"""Paper Figure 4: transferability — bit-widths searched on a source model,
retrained on a target model (reusing overlapping interaction-net params).

Claim: the transfer penalty is small compared to skipping retraining.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (LAM, SEED, STEPS, builder, dataset, print_csv,
                               run_mpe)
from repro.core.mpe import MPEConfig
from repro.train.loop import Trainer
from repro.train.optimizer import adam


def transfer(source_res, target_backbone: str):
    """Retrain `target_backbone` with the bit-widths searched on the source."""
    ds = dataset()
    build = builder(target_backbone, lam=LAM)
    bundle = build(jax.random.PRNGKey(SEED), "mpe_retrain", {
        **MPEConfig(lam=LAM)._asdict(),
        "init_emb": jnp.asarray(source_res["final_params"]["embedding"]["emb"]),
        "alpha": jnp.asarray(source_res["final_params"]["embedding"]["alpha"]),
        "beta": jnp.asarray(source_res["final_params"]["embedding"]["beta"]),
        "bits_idx": jnp.asarray(source_res["feature_bits_idx"]),
    })
    tr = Trainer(bundle["loss_fn"], bundle["params"], bundle["buffers"],
                 bundle["state"], adam(1e-3))
    tr.run(lambda s: ds.batch(s), STEPS, log_every=0)
    return bundle["eval_fn"](tr.params, bundle["buffers"], tr.state)


def main():
    rows = []
    sources = {}
    for src in ("dnn", "dcn"):
        out, res = run_mpe(src, return_result=True)
        sources[src] = res
        rows.append([f"fig4/src={src}/tgt={src}", round(out["seconds"] * 1e6),
                     f"auc={out['auc']:.4f} ratio={out['ratio']:.4f}"])
        print(rows[-1])
    for src in ("dnn", "dcn"):
        for tgt in ("dnn", "dcn"):
            if src == tgt:
                continue
            ev = transfer(sources[src], tgt)
            rows.append([f"fig4/src={src}/tgt={tgt}", 0,
                         f"auc={ev['auc']:.4f}"])
            print(rows[-1])
    return rows


if __name__ == "__main__":
    print_csv(main(), ["name", "us_per_call", "derived"])
