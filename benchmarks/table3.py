"""Paper Table 3: accuracy (AUC/Logloss) + storage ratio per method × model.

Validated claims: (i) MPE reaches the lowest ratio at ≈backbone accuracy,
(ii) QR loses accuracy even at 2×, (iii) LSQ+ holds at 6 bits, ALPT at 8,
(iv) PEP/OptFS compress little when features carry signal.
"""
from __future__ import annotations

from benchmarks.common import print_csv, run_baseline, run_mpe


def main(backbones=("dnn", "dcn"), full: bool = False):
    if full:
        backbones = ("dnn", "dcn", "deepfm", "ipnn")
    rows = []
    for bb in backbones:
        for method in ("backbone", "qr", "pep", "optfs", "alpt", "lsq"):
            r = run_baseline(bb, method)
            rows.append([f"table3/{bb}/{method}",
                         round(r["seconds"] * 1e6),
                         f"auc={r['auc']:.4f} logloss={r['logloss']:.4f} "
                         f"ratio={r['ratio']:.4f}"])
            print(rows[-1])
        r = run_mpe(bb)
        rows.append([f"table3/{bb}/mpe", round(r["seconds"] * 1e6),
                     f"auc={r['auc']:.4f} logloss={r['logloss']:.4f} "
                     f"ratio={r['ratio']:.4f}"])
        print(rows[-1])
    return rows


if __name__ == "__main__":
    print_csv(main(), ["name", "us_per_call", "derived"])
