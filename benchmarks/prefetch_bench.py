"""Tiered-cache / async-prefetch benchmark → BENCH_prefetch.json.

Three measurements (schema documented in benchmarks/README.md):

  1. **Train-loop overlap** — the same tiny-DLRM training run executed with
     the synchronous loop and with ``repro.cache.PrefetchPipeline`` staging
     batches one step ahead; reports ms/step for both (the loops are
     loss-identical — asserted in tests/test_cache.py — so the delta is pure
     overlap).
  2. **Hot-tier sweep** — a ``TieredTableStore`` over the quick-pipeline
     packed table at several hot fractions, driven by a zipfian request
     stream through ``Engine.score_tiered``: hit rate, cold bytes moved and
     per-tier storage per fraction, plus overlapped vs synchronous tiered
     scoring latency (p50) at each point.
  3. **Drift sweep** — the adaptive tier policy vs the static split on a
     popularity-shift open-loop workload (``DriftingCTR`` hard shift +
     ``run_open_loop``), with training-update writebacks interleaved. Each
     policy runs twice: once under a ``TickClock`` so every reported
     hit-rate / bytes-moved / shed / occupancy / compile number is exactly
     reproducible (these are the metrics the blocking CI bench gate diffs —
     see benchmarks/gate_metrics.json), and once on the wall clock for the
     advisory e2e p99.

Runs on CPU (the CI artifact); the same script is the measurement harness on
an accelerator, where tier placement (HBM vs host) is physical.

    PYTHONPATH=src python benchmarks/prefetch_bench.py --smoke
    PYTHONPATH=src python benchmarks/prefetch_bench.py --out benchmarks/artifacts/BENCH_prefetch.json
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax
import numpy as np

from repro.cache import (DecayAdmissionPolicy, StaticTierPolicy,
                         TieredTableStore)
from repro.data.synthetic import CTRSpec, DriftingCTR, SyntheticCTR
from repro.embeddings.table import FieldSpec
from repro.launch.serve import run_open_loop, train_packed_dlrm
from repro.models.dlrm import DLRM, DLRMConfig
from repro.serve import Engine, TickClock
from repro.train.loop import Trainer
from repro.train.optimizer import adam
from repro.zoo import dlrm_builder

FULL = dict(field_vocabs=(3000, 2000, 1500, 1000), pipeline_steps=100,
            train_steps=60, train_batch=2048, serve_steps=30, serve_batch=2048,
            cell_rows=512, hot_fractions=(0.0, 0.1, 0.25, 0.5, 0.9, 1.0),
            drift_requests=120, drift_qps=200.0, drift_batch=512,
            drift_shift_at=40, drift_shift_frac=0.4, drift_hot_frac=0.2,
            drift_halflife=32.0, drift_policy_every=2, drift_max_moves=256,
            drift_writeback_every=16)
SMOKE = dict(field_vocabs=(600, 400, 500), pipeline_steps=25,
             train_steps=20, train_batch=512, serve_steps=8, serve_batch=512,
             cell_rows=128, hot_fractions=(0.0, 0.1, 0.5, 1.0),
             drift_requests=48, drift_qps=400.0, drift_batch=256,
             drift_shift_at=12, drift_shift_frac=0.4, drift_hot_frac=0.2,
             drift_halflife=12.0, drift_policy_every=1, drift_max_moves=256,
             drift_writeback_every=8)

SERVE_STEP0 = 10_000    # serving streams start here to stay disjoint from
#                         training batches (mirrors repro.launch.serve)


def bench_train_overlap(cfg: dict) -> dict:
    """ms/step of the synchronous vs prefetch-staged training loop."""
    out = {}
    for prefetch in (False, True):
        spec = CTRSpec(field_vocabs=cfg["field_vocabs"],
                       batch_size=cfg["train_batch"], seed=0)
        ds = SyntheticCTR(spec)
        fields = tuple(FieldSpec(f"f{i}", v)
                       for i, v in enumerate(spec.field_vocabs))
        base = DLRMConfig(fields=fields, d_embed=16, mlp_hidden=(64, 32),
                          backbone="dnn")
        b = dlrm_builder(base, ds.expected_frequencies())(
            jax.random.PRNGKey(0), "plain", {})
        tr = Trainer(b["loss_fn"], b["params"], b["buffers"], b["state"],
                     adam(1e-3))
        tr.run(lambda s: ds.batch(s), 3, log_every=0,
               prefetch=prefetch)                     # compile + warm outside
        t0 = time.perf_counter()
        tr.run(lambda s: ds.batch(s), 3 + cfg["train_steps"], log_every=0,
               prefetch=prefetch)
        ms = (time.perf_counter() - t0) * 1e3 / cfg["train_steps"]
        out["overlapped_ms_per_step" if prefetch
            else "synchronous_ms_per_step"] = round(ms, 3)
    out["speedup"] = round(out["synchronous_ms_per_step"]
                           / max(out["overlapped_ms_per_step"], 1e-9), 3)
    return out


def bench_hot_sweep(cfg: dict, art) -> list[dict]:
    """Hit rate / bytes moved / tiered-score latency per hot fraction."""
    serve_cfg, params, state, buffers, spec, res = art
    freqs = SyntheticCTR(spec).expected_frequencies()
    req_ds = SyntheticCTR(spec._replace(batch_size=cfg["serve_batch"]))

    points = []
    for hf in cfg["hot_fractions"]:
        store = TieredTableStore(res["packed_table"], res["packed_meta"],
                                 freqs, hf)
        engine = Engine()
        engine.register_tiered_model(
            "dlrm", DLRM, serve_cfg, params, state, buffers, store,
            shapes={"tiered": cfg["cell_rows"]})
        timings = {True: [], False: []}
        for step in range(cfg["serve_steps"]):
            ids = req_ds.batch(10_000 + step)["ids"]
            for overlap in (False, True):
                t0 = time.perf_counter()
                engine.score_tiered(ids, overlap=overlap)
                timings[overlap].append((time.perf_counter() - t0) * 1e3)
        skip = min(2, cfg["serve_steps"] - 1)
        c = store.counters()
        points.append({
            "hot_fraction": hf,
            "hit_rate": round(c["hit_rate"], 4),
            "bytes_moved": c["bytes_moved"],
            "hot_bytes": c["hot_bytes"],
            "cold_bytes": c["cold_bytes"],
            "score_p50_ms_synchronous": round(
                float(np.percentile(timings[False][skip:], 50)), 3),
            "score_p50_ms_overlapped": round(
                float(np.percentile(timings[True][skip:], 50)), 3),
        })
        print(f"[prefetch_bench] hot={hf:<5} hit_rate={c['hit_rate']:.3f} "
              f"moved={c['bytes_moved']}B "
              f"sync_p50={points[-1]['score_p50_ms_synchronous']}ms "
              f"overlap_p50={points[-1]['score_p50_ms_overlapped']}ms")
    return points


def _drift_run(cfg: dict, art, policy_name: str, clock):
    """One open-loop popularity-shift replay under ``policy_name``.

    Returns (metrics dict, engine). With a ``TickClock`` every metric in the
    dict is a pure function of the config — the bench gate's contract; with
    ``clock=None`` the run rides the wall clock and only its
    ``request_summary`` p99 is meaningful.
    """
    serve_cfg, params, state, buffers, spec, res = art
    freqs = SyntheticCTR(spec).expected_frequencies()
    master = np.asarray(res["final_params"]["embedding"]["emb"])
    offs = np.asarray(buffers["offsets"], np.int64)
    n = cfg["drift_requests"]
    shift_at = cfg["drift_shift_at"]
    steady_mark = shift_at + (n - shift_at) // 2   # counters snapshot here

    store = TieredTableStore(res["packed_table"], res["packed_meta"],
                             freqs, cfg["drift_hot_frac"])
    engine = Engine(clock=clock) if clock is not None else Engine()
    engine.register_tiered_model(
        "dlrm", DLRM, serve_cfg, params, state, buffers, store,
        shapes={"tiered": cfg["cell_rows"]})
    if policy_name == "decay":
        policy = DecayAdmissionPolicy(store.meta["n"],
                                      halflife=cfg["drift_halflife"],
                                      max_moves=cfg["drift_max_moves"])
    else:
        policy = StaticTierPolicy()
    engine.attach_tier_policy(policy, every=cfg["drift_policy_every"])

    req_ds = DriftingCTR(spec._replace(batch_size=cfg["drift_batch"]),
                         shift_at=shift_at,
                         shift_frac=cfg["drift_shift_frac"],
                         step0=SERVE_STEP0)
    wb_every = cfg["drift_writeback_every"]
    snap = {}

    def on_submit(i, ids):
        if i == steady_mark:
            snap.update(store.counters())
        if wb_every and i and i % wb_every == 0:
            gids = np.unique(np.asarray(ids, np.int64) + offs[None, :])
            engine.writeback_embeddings(gids, master[gids])

    compiles0 = engine.compile_count
    ol = run_open_loop(engine,
                       lambda i: req_ds.batch(SERVE_STEP0 + i)["ids"],
                       n, cfg["drift_qps"], kind="tiered",
                       on_submit=on_submit)
    c = store.counters()
    hot_d = c["hot_lookups"] - snap.get("hot_lookups", 0)
    tot_d = hot_d + c["cold_lookups"] - snap.get("cold_lookups", 0)
    metrics = {
        "policy": policy_name,
        "hit_rate": round(c["hit_rate"], 4),
        "steady_hit_rate": round(hot_d / tot_d, 4) if tot_d else 1.0,
        "bytes_moved": int(c["bytes_moved"]),
        "promotions": int(c["promotions"]),
        "demotions": int(c["demotions"]),
        "promote_bytes": int(c["promote_bytes"]),
        "writebacks": int(c["writebacks"]),
        "writeback_bytes": int(c["writeback_bytes"]),
        "completed": int(ol["completed"]),
        "shed": int(ol["shed"]),
        "compiles_during_run": int(engine.compile_count - compiles0),
    }
    return metrics, engine


def bench_drift(cfg: dict, art) -> dict:
    """Adaptive (decay-admission) vs static tier policy on a popularity
    shift, writebacks interleaved. Deterministic metrics come from a
    ``TickClock`` replay; the advisory ``e2e_p99_ms`` from a second
    wall-clock run of the identical trajectory inputs."""
    n = cfg["drift_requests"]
    shift_at = cfg["drift_shift_at"]
    points = []
    for name in ("static", "decay"):
        det, _ = _drift_run(cfg, art, name, TickClock())
        _, wall_engine = _drift_run(cfg, art, name, None)
        summary = wall_engine.request_summary(skip_warmup=2)
        det["e2e_p99_ms"] = round(summary["tiered"]["latency"]["p99_ms"], 3)
        points.append(det)
        print(f"[prefetch_bench] drift policy={name:<6} "
              f"hit_rate={det['hit_rate']:.3f} "
              f"steady={det['steady_hit_rate']:.3f} "
              f"moved={det['bytes_moved']}B "
              f"promotions={det['promotions']} "
              f"compiles={det['compiles_during_run']} "
              f"p99={det['e2e_p99_ms']}ms")
    return {
        "requests": n,
        "shift_at": shift_at,
        "shift_frac": cfg["drift_shift_frac"],
        "hot_frac": cfg["drift_hot_frac"],
        "steady_from": shift_at + (n - shift_at) // 2,
        "points": points,
    }


def run(cfg: dict) -> dict:
    train = bench_train_overlap(cfg)
    print(f"[prefetch_bench] train: sync={train['synchronous_ms_per_step']}ms "
          f"overlapped={train['overlapped_ms_per_step']}ms "
          f"(x{train['speedup']})")
    art = train_packed_dlrm(field_vocabs=cfg["field_vocabs"],
                            train_steps=cfg["pipeline_steps"],
                            train_batch=cfg["train_batch"])
    return {
        "config": {k: (list(v) if isinstance(v, tuple) else v)
                   for k, v in cfg.items()},
        "env": {"jax": jax.__version__, "backend": jax.default_backend(),
                "device_count": jax.device_count(),
                "platform": platform.platform()},
        "train": train,
        "tiers": bench_hot_sweep(cfg, art),
        "drift": bench_drift(cfg, art),
        "unix_time": int(time.time()),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny table + short streams (the CI data point)")
    ap.add_argument("--out", default=None,
                    help="output path (default benchmarks/artifacts/"
                         "BENCH_prefetch.json)")
    args = ap.parse_args(argv)

    out_path = args.out or os.path.join("benchmarks", "artifacts",
                                        "BENCH_prefetch.json")
    result = run(dict(SMOKE if args.smoke else FULL,
                      mode="smoke" if args.smoke else "full"))
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[prefetch_bench] wrote {out_path}")


if __name__ == "__main__":
    main()
