"""Tiered-cache / async-prefetch benchmark → BENCH_prefetch.json.

Two measurements (schema documented in benchmarks/README.md):

  1. **Train-loop overlap** — the same tiny-DLRM training run executed with
     the synchronous loop and with ``repro.cache.PrefetchPipeline`` staging
     batches one step ahead; reports ms/step for both (the loops are
     loss-identical — asserted in tests/test_cache.py — so the delta is pure
     overlap).
  2. **Hot-tier sweep** — a ``TieredTableStore`` over the quick-pipeline
     packed table at several hot fractions, driven by a zipfian request
     stream through ``Engine.score_tiered``: hit rate, cold bytes moved and
     per-tier storage per fraction, plus overlapped vs synchronous tiered
     scoring latency (p50) at each point.

Runs on CPU (the CI artifact); the same script is the measurement harness on
an accelerator, where tier placement (HBM vs host) is physical.

    PYTHONPATH=src python benchmarks/prefetch_bench.py --smoke
    PYTHONPATH=src python benchmarks/prefetch_bench.py --out benchmarks/artifacts/BENCH_prefetch.json
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax
import numpy as np

from repro.cache import TieredTableStore
from repro.data.synthetic import CTRSpec, SyntheticCTR
from repro.embeddings.table import FieldSpec
from repro.launch.serve import train_packed_dlrm
from repro.models.dlrm import DLRM, DLRMConfig
from repro.serve import Engine
from repro.train.loop import Trainer
from repro.train.optimizer import adam
from repro.zoo import dlrm_builder

FULL = dict(field_vocabs=(3000, 2000, 1500, 1000), pipeline_steps=100,
            train_steps=60, train_batch=2048, serve_steps=30, serve_batch=2048,
            cell_rows=512, hot_fractions=(0.0, 0.1, 0.25, 0.5, 0.9, 1.0))
SMOKE = dict(field_vocabs=(600, 400, 500), pipeline_steps=25,
             train_steps=20, train_batch=512, serve_steps=8, serve_batch=512,
             cell_rows=128, hot_fractions=(0.0, 0.1, 0.5, 1.0))


def bench_train_overlap(cfg: dict) -> dict:
    """ms/step of the synchronous vs prefetch-staged training loop."""
    out = {}
    for prefetch in (False, True):
        spec = CTRSpec(field_vocabs=cfg["field_vocabs"],
                       batch_size=cfg["train_batch"], seed=0)
        ds = SyntheticCTR(spec)
        fields = tuple(FieldSpec(f"f{i}", v)
                       for i, v in enumerate(spec.field_vocabs))
        base = DLRMConfig(fields=fields, d_embed=16, mlp_hidden=(64, 32),
                          backbone="dnn")
        b = dlrm_builder(base, ds.expected_frequencies())(
            jax.random.PRNGKey(0), "plain", {})
        tr = Trainer(b["loss_fn"], b["params"], b["buffers"], b["state"],
                     adam(1e-3))
        tr.run(lambda s: ds.batch(s), 3, log_every=0,
               prefetch=prefetch)                     # compile + warm outside
        t0 = time.perf_counter()
        tr.run(lambda s: ds.batch(s), 3 + cfg["train_steps"], log_every=0,
               prefetch=prefetch)
        ms = (time.perf_counter() - t0) * 1e3 / cfg["train_steps"]
        out["overlapped_ms_per_step" if prefetch
            else "synchronous_ms_per_step"] = round(ms, 3)
    out["speedup"] = round(out["synchronous_ms_per_step"]
                           / max(out["overlapped_ms_per_step"], 1e-9), 3)
    return out


def bench_hot_sweep(cfg: dict) -> list[dict]:
    """Hit rate / bytes moved / tiered-score latency per hot fraction."""
    serve_cfg, params, state, buffers, spec, res = train_packed_dlrm(
        field_vocabs=cfg["field_vocabs"], train_steps=cfg["pipeline_steps"],
        train_batch=cfg["train_batch"])
    freqs = SyntheticCTR(spec).expected_frequencies()
    req_ds = SyntheticCTR(spec._replace(batch_size=cfg["serve_batch"]))

    points = []
    for hf in cfg["hot_fractions"]:
        store = TieredTableStore(res["packed_table"], res["packed_meta"],
                                 freqs, hf)
        engine = Engine()
        engine.register_tiered_model(
            "dlrm", DLRM, serve_cfg, params, state, buffers, store,
            shapes={"tiered": cfg["cell_rows"]})
        timings = {True: [], False: []}
        for step in range(cfg["serve_steps"]):
            ids = req_ds.batch(10_000 + step)["ids"]
            for overlap in (False, True):
                t0 = time.perf_counter()
                engine.score_tiered(ids, overlap=overlap)
                timings[overlap].append((time.perf_counter() - t0) * 1e3)
        skip = min(2, cfg["serve_steps"] - 1)
        c = store.counters()
        points.append({
            "hot_fraction": hf,
            "hit_rate": round(c["hit_rate"], 4),
            "bytes_moved": c["bytes_moved"],
            "hot_bytes": c["hot_bytes"],
            "cold_bytes": c["cold_bytes"],
            "score_p50_ms_synchronous": round(
                float(np.percentile(timings[False][skip:], 50)), 3),
            "score_p50_ms_overlapped": round(
                float(np.percentile(timings[True][skip:], 50)), 3),
        })
        print(f"[prefetch_bench] hot={hf:<5} hit_rate={c['hit_rate']:.3f} "
              f"moved={c['bytes_moved']}B "
              f"sync_p50={points[-1]['score_p50_ms_synchronous']}ms "
              f"overlap_p50={points[-1]['score_p50_ms_overlapped']}ms")
    return points


def run(cfg: dict) -> dict:
    train = bench_train_overlap(cfg)
    print(f"[prefetch_bench] train: sync={train['synchronous_ms_per_step']}ms "
          f"overlapped={train['overlapped_ms_per_step']}ms "
          f"(x{train['speedup']})")
    return {
        "config": {k: (list(v) if isinstance(v, tuple) else v)
                   for k, v in cfg.items()},
        "env": {"jax": jax.__version__, "backend": jax.default_backend(),
                "device_count": jax.device_count(),
                "platform": platform.platform()},
        "train": train,
        "tiers": bench_hot_sweep(cfg),
        "unix_time": int(time.time()),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny table + short streams (the CI data point)")
    ap.add_argument("--out", default=None,
                    help="output path (default benchmarks/artifacts/"
                         "BENCH_prefetch.json)")
    args = ap.parse_args(argv)

    out_path = args.out or os.path.join("benchmarks", "artifacts",
                                        "BENCH_prefetch.json")
    result = run(dict(SMOKE if args.smoke else FULL,
                      mode="smoke" if args.smoke else "full"))
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[prefetch_bench] wrote {out_path}")


if __name__ == "__main__":
    main()
