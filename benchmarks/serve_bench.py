"""Serving-latency benchmark → BENCH_serve.json (the perf-trajectory point).

Runs the full packed-table serving path — MPE pipeline, engine registration,
a p99 traffic stream plus one bulk job — and emits a machine-readable record:
per-cell p50/p99 with the Figure-5 lookup-vs-compute split, cell-cache
counters, compile seconds, and the table's compression stats. CI runs the
``--smoke`` variant on CPU every PR and uploads the artifact, so the serve
latency trajectory accumulates one data point per change.

    PYTHONPATH=src python benchmarks/serve_bench.py --smoke
    PYTHONPATH=src python benchmarks/serve_bench.py --out benchmarks/artifacts/BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax

from repro.data.synthetic import SyntheticCTR
from repro.launch.serve import build_engine, train_packed_dlrm

FULL = dict(field_vocabs=(3000, 2000, 1500, 1000, 800, 700), train_steps=150,
            steps=50, batch=300, bulk=20_000, p99_rows=512, bulk_rows=4096)
SMOKE = dict(field_vocabs=(600, 400, 500, 300), train_steps=30,
             steps=10, batch=100, bulk=1500, p99_rows=128, bulk_rows=1024)


def run(cfg: dict) -> dict:
    t0 = time.time()
    serve_cfg, params, state, buffers, spec, res = train_packed_dlrm(
        field_vocabs=cfg["field_vocabs"], train_steps=cfg["train_steps"])
    train_s = time.time() - t0

    t0 = time.time()
    engine = build_engine(serve_cfg, params, state, buffers,
                          p99_rows=cfg["p99_rows"], bulk_rows=cfg["bulk_rows"])
    register_s = time.time() - t0

    req_ds = SyntheticCTR(spec._replace(batch_size=cfg["batch"]))
    for step in range(cfg["steps"]):
        engine.score(req_ds.batch(10_000 + step)["ids"])
    bulk_ds = SyntheticCTR(spec._replace(batch_size=cfg["bulk"]))
    engine.score(bulk_ds.batch(99_999)["ids"])

    skip = min(3, cfg["steps"] - 1)
    print(engine.stats.format_table(skip_warmup=skip))
    return {
        "config": {k: (list(v) if isinstance(v, tuple) else v)
                   for k, v in cfg.items()},
        "env": {"jax": jax.__version__,
                "backend": jax.default_backend(),
                "device_count": jax.device_count(),
                "platform": platform.platform()},
        "train_s": round(train_s, 2),
        "register_s": round(register_s, 2),
        "cells": engine.summary(skip_warmup=skip),
        "cache": engine.counters(),
        "storage_ratio": res["storage_ratio"],
        "avg_bits": res["avg_bits"],
        "packed_bytes": res["packed_bytes"],
        "unix_time": int(time.time()),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny table + short stream (the CI data point)")
    ap.add_argument("--out", default=None,
                    help="output path (default benchmarks/artifacts/BENCH_serve.json)")
    args = ap.parse_args(argv)

    out_path = args.out or os.path.join("benchmarks", "artifacts",
                                        "BENCH_serve.json")
    result = run(dict(SMOKE if args.smoke else FULL,
                      mode="smoke" if args.smoke else "full"))
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)

    print(f"[serve_bench] cache={result['cache']} "
          f"ratio={result['storage_ratio']:.4f}")
    print(f"[serve_bench] wrote {out_path}")


if __name__ == "__main__":
    main()
