"""Paper Figure 5: inference latency split into table lookup vs computation.

Methods: backbone (fp32 table), LSQ+ (uniform packed int6 emulated via the
packed table with a single width), MPE (mixed packed). The paper's finding —
lookup is a small slice of end-to-end latency, dequantization costs a little
— is measured here wall-clock on CPU; on TPU the same harness reads traces.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import SEED, builder, dataset, print_csv, run_mpe
from repro.core.inference import packed_lookup
from repro.models.dlrm import DLRM

BATCH = 10_000  # paper §5.5


def _time(fn, *args, reps=15):
    fn(*args)  # compile+warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e3  # ms


def main():
    ds = dataset()
    out, res = run_mpe("dnn", return_result=True)
    ids = jnp.asarray(ds.batch(99)["ids"])

    base_cfg = builder("dnn")(jax.random.PRNGKey(SEED), "plain", {})["cfg"]

    # --- fp32 backbone
    bundle = builder("dnn")(jax.random.PRNGKey(SEED), "plain", {})
    params, bufs, state = bundle["params"], bundle["buffers"], bundle["state"]
    gids = ids + bufs["offsets"][None, :]

    lookup_fp = jax.jit(lambda p, i: jnp.take(p["embedding"]["emb"], i, axis=0))
    full_fp = jax.jit(lambda p, i: DLRM.apply(p, bufs, state, {"ids": i},
                                              base_cfg, train=False)[0])
    t_lookup_fp = _time(lookup_fp, params, gids)
    t_full_fp = _time(full_fp, params, ids)

    # --- MPE packed
    table, meta = res["packed_table"], res["packed_meta"]
    lookup_mpe = jax.jit(lambda t, i: packed_lookup(t, meta, i))
    t_lookup_mpe = _time(lookup_mpe, table, gids)

    serve_cfg = base_cfg._replace(compressor="packed",
                                  comp_cfg={"bits": meta["bits"],
                                            "d": meta["d"], "n": meta["n"]})
    sp = {k: v for k, v in res["final_params"].items() if k != "embedding"}
    sp["embedding"] = table
    sbufs = dict(res["buffers"], embedding={})
    full_mpe = jax.jit(lambda p, i: DLRM.apply(p, sbufs, res["state"],
                                               {"ids": i}, serve_cfg,
                                               train=False)[0])
    t_full_mpe = _time(full_mpe, sp, ids)

    rows = [
        ["fig5/backbone/lookup_ms", round(t_lookup_fp * 1e3),
         f"{t_lookup_fp:.3f}ms"],
        ["fig5/backbone/total_ms", round(t_full_fp * 1e3), f"{t_full_fp:.3f}ms"],
        ["fig5/mpe/lookup_ms", round(t_lookup_mpe * 1e3),
         f"{t_lookup_mpe:.3f}ms (packed dequant)"],
        ["fig5/mpe/total_ms", round(t_full_mpe * 1e3), f"{t_full_mpe:.3f}ms"],
        ["fig5/mpe/storage", 0, f"bytes={res['packed_bytes']} "
         f"ratio={res['storage_ratio']:.4f}"],
    ]
    for r in rows:
        print(r)
    return rows


if __name__ == "__main__":
    print_csv(main(), ["name", "us_per_call", "derived"])
