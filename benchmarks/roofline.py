"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh) cell:
    compute term    = flops_per_device / PEAK_FLOPS
    memory term     = hbm_bytes_per_device / HBM_BW
    collective term = collective_bytes_per_device / ICI_BW
    MODEL_FLOPS     = analytical useful flops (6·N·D train, 2·N·D serve;
                      MoE uses active params)
    usefulness      = MODEL_FLOPS / (chips · flops_per_device)

The dominant term is the projected bottleneck; 'roofline fraction' is
MODEL_FLOPS/chips/PEAK divided by the dominant term — i.e. how close the cell
would run to the compute roofline if it achieved the analyzed schedule.

Usage: python -m benchmarks.roofline [--dir benchmarks/artifacts] [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

# analytical parameter counts (active params for MoE)
PARAMS = {
    "starcoder2-7b": dict(total=7.2e9, active=7.2e9),
    "qwen3-32b": dict(total=32.8e9, active=32.8e9),
    "internlm2-1.8b": dict(total=1.9e9, active=1.9e9),
    "deepseek-moe-16b": dict(total=16.4e9, active=2.8e9),
    "grok-1-314b": dict(total=316e9, active=80e9),
}


def model_flops(cell: dict) -> float | None:
    meta = cell["meta"]
    arch = cell["cell"].split("/")[0]
    if meta.get("family") == "lm":
        p = PARAMS.get(arch)
        if p is None:
            return None
        tokens = meta.get("tokens", 0)
        if meta["kind"] == "train":
            return 6.0 * p["active"] * tokens
        if meta["kind"] == "prefill":
            return 2.0 * p["active"] * tokens
        # decode: matmul flops + KV attention flops
        kv = meta.get("kv_len", 0)
        return 2.0 * p["active"] * tokens + 4.0 * tokens * kv * 1e4
    return None  # recsys/gnn cells are gather/scatter bound; flops ≠ utility


def collective_breakdown(coll: dict) -> dict:
    """{kind: {"bytes", "count"}} — the per-collective byte counts, without
    the scalar ``total_bytes`` entry."""
    return {k: v for k, v in coll.items() if isinstance(v, dict)}


def format_collectives(coll: dict) -> str:
    parts = [f"{k}={v['bytes']:.3e}B x{v['count']}"
             for k, v in sorted(collective_breakdown(coll).items())]
    return " ".join(parts) if parts else "none"


def analyze_cell(cell: dict) -> dict:
    chips = cell["n_chips"]
    t_compute = cell["flops_per_device"] / PEAK_FLOPS_BF16
    t_memory = cell["hbm_bytes_per_device"] / HBM_BW
    t_coll = cell["collectives_per_device"]["total_bytes"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cell)
    name = cell["cell"]
    if cell.get("variant"):
        name += f" [{cell['variant']}]"
    out = {
        "cell": name, "mesh": cell["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf,
        "collectives": collective_breakdown(cell["collectives_per_device"]),
    }
    if mf:
        out["usefulness"] = mf / (chips * cell["flops_per_device"] + 1e-30)
        ideal = mf / chips / PEAK_FLOPS_BF16
        out["roofline_fraction"] = ideal / max(terms[dominant], 1e-30)
    return out


def shard_bench_rows(path: str) -> list:
    """Per-collective byte counts of the shard_map'd cells from a
    ``BENCH_shard.json`` artifact (benchmarks/shard_bench.py) — the sharded
    lookup/serve/train counterpart of the dry-run cells, plus the
    psum-vs-a2a crossover rows (measured all-to-all bytes per bucket
    capacity and bit-width)."""
    with open(path) as f:
        bench = json.load(f)
    rows = []
    for mesh_name, kernels in bench.get("kernels", {}).items():
        for kname, rec in kernels.items():
            if "collectives" in rec:
                rows.append({"cell": f"shard/{kname}", "mesh": mesh_name,
                             "p50_ms": rec.get("p50_ms"),
                             "collectives": collective_breakdown(
                                 rec["collectives"])})
    for mesh_name, rec in bench.get("train", {}).items():
        if "collectives" in rec:
            rows.append({"cell": "shard/train_step", "mesh": mesh_name,
                         "p50_ms": rec.get("ms_per_step"),
                         "collectives": collective_breakdown(
                             rec["collectives"])})
    # psum-vs-a2a crossover sweep: one psum reference row per bit-width, one
    # a2a row per (bit-width, bucket capacity) — this is where the
    # all-to-all byte attribution shows up next to psum/all-gather
    for mesh_name, bits_rows in bench.get("crossover", {}).items():
        for bname, caps in bits_rows.items():
            ref = caps.get("full") or next(iter(caps.values()))
            rows.append({"cell": f"shard/lookup_psum[{bname}]",
                         "mesh": mesh_name,
                         "p50_ms": ref.get("psum_p50_ms"),
                         "collectives": collective_breakdown(
                             ref["psum_collectives"])})
            for cname, rec in caps.items():
                rows.append({"cell": f"shard/lookup_a2a[{bname},{cname}]",
                             "mesh": mesh_name,
                             "p50_ms": rec.get("a2a_p50_ms"),
                             "collectives": collective_breakdown(
                                 rec["a2a_collectives"])})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/artifacts")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--collectives", action="store_true",
                    help="also print the per-collective byte breakdown "
                         "(all-reduce / all-gather / reduce-scatter / "
                         "all-to-all / collective-permute) per cell")
    ap.add_argument("--shard-bench", default=None,
                    help="a BENCH_shard.json (benchmarks/shard_bench.py): "
                         "report the measured shard_map cells' per-collective "
                         "bytes alongside the dry-run projections")
    args = ap.parse_args()
    rows = []
    for path in sorted(glob.glob(os.path.join(args.dir, "dryrun_*.json"))):
        with open(path) as f:
            cell = json.load(f)
        if cell["mesh"] != args.mesh:
            continue
        r = analyze_cell(cell)
        rows.append(r)
    print(f"{'cell':58s} {'compute':>10s} {'memory':>10s} {'collective':>11s} "
          f"{'dominant':>10s} {'roofline%':>9s} {'useful%':>8s}")
    for r in rows:
        rf = f"{100*r.get('roofline_fraction', float('nan')):.1f}" \
            if "roofline_fraction" in r else "-"
        uf = f"{100*r.get('usefulness', float('nan')):.1f}" \
            if "usefulness" in r else "-"
        print(f"{r['cell']:58s} {r['t_compute_s']:10.4f} {r['t_memory_s']:10.4f} "
              f"{r['t_collective_s']:11.4f} {r['dominant']:>10s} {rf:>9s} {uf:>8s}")
        if args.collectives and r["collectives"]:
            print(f"{'':4s}collectives: {format_collectives(r['collectives'])}")
    if args.shard_bench:
        srows = shard_bench_rows(args.shard_bench)
        print(f"\nshard_map cells ({args.shard_bench}) — measured "
              f"per-collective bytes/device:")
        for r in srows:
            ms = f"{r['p50_ms']:.3f}ms" if r.get("p50_ms") is not None else "-"
            print(f"  {r['cell']:24s} {r['mesh']:>6s} {ms:>10s}  "
                  f"{format_collectives(r['collectives'])}")
        rows += srows
    return rows


if __name__ == "__main__":
    main()
