"""Paper Table 4: retraining ablation (DNN).

  w/o retraining < LTH retraining < MPE retraining (accuracy).
"""
from __future__ import annotations

from benchmarks.common import print_csv, run_mpe


def main():
    rows = []
    for mode in ("none", "lth", "mpe"):
        r = run_mpe("dnn", retrain_mode=mode)
        rows.append([f"table4/{mode}", round(r["seconds"] * 1e6),
                     f"auc={r['auc']:.4f} logloss={r['logloss']:.4f} "
                     f"ratio={r['ratio']:.4f}"])
        print(rows[-1])
    return rows


if __name__ == "__main__":
    print_csv(main(), ["name", "us_per_call", "derived"])
