"""Paper Figure 3: AUC under varying compression ratios (DNN model).

MPE sweeps λ; LSQ+ sweeps the uniform bit-width; QR sweeps k. At matched
ratio MPE should dominate the AUC frontier.
"""
from __future__ import annotations

from benchmarks.common import print_csv, run_baseline, run_mpe


def main():
    rows = []
    for lam in (1e-5, 3e-5, 1e-4, 3e-4):
        r = run_mpe("dnn", lam=lam)
        rows.append([f"fig3/mpe/lam={lam:g}", round(r["seconds"] * 1e6),
                     f"ratio={r['ratio']:.4f} auc={r['auc']:.4f}"])
        print(rows[-1])
    for bits in (2, 3, 4, 6):
        r = run_baseline("dnn", "lsq", comp_cfg_override={"bits": bits})
        rows.append([f"fig3/lsq/b={bits}", round(r["seconds"] * 1e6),
                     f"ratio={r['ratio']:.4f} auc={r['auc']:.4f}"])
        print(rows[-1])
    for k in (2, 4):
        r = run_baseline("dnn", "qr", comp_cfg_override={"k": k})
        rows.append([f"fig3/qr/k={k}", round(r["seconds"] * 1e6),
                     f"ratio={r['ratio']:.4f} auc={r['auc']:.4f}"])
        print(rows[-1])
    return rows


if __name__ == "__main__":
    print_csv(main(), ["name", "us_per_call", "derived"])
