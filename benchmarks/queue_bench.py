"""Request-lifecycle benchmark → BENCH_queue.json (queue/scheduler perf point).

Three experiments over the admission-queue → coalescing-scheduler →
compiled-cell stack:

  1. **Open-loop QPS sweep** — seeded Poisson arrivals at each offered rate
     drive `run_open_loop` (virtual-timeline replay; queue-wait is virtual,
     assembly/compute measured wall-clock). Per point: p50/p99 end-to-end
     latency, the queue/assembly/compute split, goodput, shed rate and
     per-cell occupancy. Each point gets a fresh engine sharing the warm
     `CellCache`, so sweep points are independent and recompiles stay zero.
  2. **Two-tenant skewed-priority sweep** — `run_open_loop_mix` merges a
     latency tenant (priority 0, deadline) and a bulk tenant (priority 1,
     queue-share quota, no deadline) at each total offered rate, through a
     watermark-shedding queue. Per point: per-stream goodput/shed and the
     per-lane (`kind:p<priority>`) latency split — the multi-tenant SLO
     numbers `engine.request_summary(by=...)` surfaces.
  3. **Continuous vs restart decode** — the same LM and prompt set generated
     (a) through the continuous-batching decode lane (sequences join/leave a
     slot-pooled KV cache between steps) and (b) per-request through the
     classic decode cell (one sequence at a time, batch slots idle). Reports
     tokens/s for both and the speedup.

CI runs `--smoke` on CPU every PR, uploads the artifact and diffs it against
`benchmarks/baselines/BENCH_queue.json` via `scripts/bench_compare.py`.

    PYTHONPATH=src python benchmarks/queue_bench.py --smoke
    PYTHONPATH=src python benchmarks/queue_bench.py --out benchmarks/artifacts/BENCH_queue.json
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax
import numpy as np

from repro.data.synthetic import SyntheticCTR
from repro.launch.serve import (build_engine, run_open_loop,
                                run_open_loop_mix, train_packed_dlrm)
from repro.serve import (Engine, LatencyStats, RequestStats, TenantQuota,
                         lm_decode_cell, lm_decode_slotted_cell)

FULL = dict(field_vocabs=(3000, 2000, 1500, 1000), train_steps=120,
            requests=120, batch=60, p99_rows=512, bulk_rows=4096,
            qps_sweep=(50.0, 200.0, 800.0), deadline_ms=2000.0,
            queue_capacity=256, mix_sweep=(100.0, 800.0),
            lm=dict(slots=4, max_len=48, prompts=24, prompt_len=8, max_new=16))
SMOKE = dict(field_vocabs=(600, 400, 500), train_steps=30,
             requests=40, batch=40, p99_rows=128, bulk_rows=1024,
             qps_sweep=(50.0, 400.0), deadline_ms=2000.0,
             queue_capacity=256, mix_sweep=(100.0, 600.0),
             lm=dict(slots=2, max_len=24, prompts=8, prompt_len=4, max_new=8))


def sweep_point(base_engine, cfg, spec, qps: float, model_args) -> dict:
    """One offered-QPS point on a fresh engine sharing the warm cell cache."""
    engine = Engine(mesh=base_engine.mesh, cache=base_engine.cache,
                    queue_capacity=cfg["queue_capacity"])
    engine.register_packed_model(*model_args,
                                 shapes={"serve_p99": cfg["p99_rows"],
                                         "serve_bulk": cfg["bulk_rows"]})
    req_ds = SyntheticCTR(spec._replace(batch_size=cfg["batch"]))
    engine.score(req_ds.batch(9_999)["ids"])        # warm dispatch path
    # reset the recorders so the warm-up dispatch skews neither the latency
    # percentiles nor the occupancy baseline
    engine.stats = LatencyStats()
    engine.rstats = RequestStats()
    ol = run_open_loop(engine, lambda i: req_ds.batch(10_000 + i)["ids"],
                       cfg["requests"], qps, seed=0,
                       deadline_ms=cfg["deadline_ms"])
    rs = engine.request_summary()["score"]
    occ = engine.counters()["occupancy"]
    offered = cfg["requests"]
    return {
        "offered_qps": qps,
        "goodput_qps": ol["goodput_qps"],
        "completed": ol["completed"],
        "shed": ol["shed"],
        "shed_rate": ol["shed"] / offered if offered else 0.0,
        "latency_p50_ms": rs["latency"]["p50_ms"],
        "latency_p99_ms": rs["latency"]["p99_ms"],
        "queue_p50_ms": rs["queue"]["p50_ms"],
        "assembly_p50_ms": rs["assembly"]["p50_ms"],
        "compute_p50_ms": rs["compute"]["p50_ms"],
        "occupancy": {cell: v["occupancy"] for cell, v in occ.items()},
    }


def mix_point(base_engine, cfg, spec, qps: float, model_args) -> dict:
    """One two-tenant point at a total offered rate ``qps``: a latency
    tenant (priority 0, deadline) and a bulk tenant (priority 1, queue-share
    quota, no deadline) interleave through a watermark-shedding queue."""
    engine = Engine(mesh=base_engine.mesh, cache=base_engine.cache,
                    queue_capacity=cfg["queue_capacity"],
                    quotas={"bulk": TenantQuota(
                        max_queued=cfg["queue_capacity"] // 4,
                        max_inflight_rows=None)},
                    shed_watermark=0.75)
    engine.register_packed_model(*model_args,
                                 shapes={"serve_p99": cfg["p99_rows"],
                                         "serve_bulk": cfg["bulk_rows"]})
    req_ds = SyntheticCTR(spec._replace(batch_size=cfg["batch"]))
    engine.score(req_ds.batch(19_999)["ids"])       # warm dispatch path
    engine.stats = LatencyStats()
    engine.rstats = RequestStats()
    n = cfg["requests"]
    streams = [
        {"tenant": "latency", "qps": qps * 0.3, "n_requests": n // 2,
         "priority": 0, "deadline_ms": cfg["deadline_ms"]},
        {"tenant": "bulk", "qps": qps * 0.7, "n_requests": n - n // 2,
         "priority": 1},
    ]
    mix = run_open_loop_mix(engine,
                            lambda i, _batch: req_ds.batch(20_000 + i)["ids"],
                            streams, seed=0)
    per_lane = {
        lane: {"count": s["count"],
               "latency_p50_ms": s["latency"]["p50_ms"],
               "latency_p99_ms": s["latency"]["p99_ms"],
               "queue_p50_ms": s["queue"]["p50_ms"]}
        for lane, s in engine.request_summary(by="lane").items()}
    qc = engine.counters()["queue"]
    return {
        "offered_qps": qps,
        "per_stream": mix["per_stream"],
        "per_lane": per_lane,
        "shed": {k: qc[k] for k in ("shed_full", "shed_deadline",
                                    "shed_quota", "shed_load")},
    }


def decode_experiment(cfg: dict) -> dict:
    """Continuous-batching vs per-request ("restart") decode throughput."""
    from repro.models.lm import LM, LMConfig
    lm = cfg["lm"]
    lcfg = LMConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                    head_dim=16, d_ff=128, vocab=128, remat=False)
    params, buffers = LM.init(jax.random.PRNGKey(0), lcfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, lcfg.vocab, size=rng.integers(
        2, lm["prompt_len"] + 1)).astype(np.int32) for _ in range(lm["prompts"])]

    # continuous batching: all prompts share the slot pool
    eng = Engine()
    eng.register(lm_decode_slotted_cell(lcfg, params, buffers,
                                        batch=lm["slots"],
                                        max_len=lm["max_len"], arch="lm"))
    warm = eng.submit_decode(prompts[0], 2)
    eng.drain()
    eng.poll(warm)
    t0 = time.perf_counter()
    tickets = [eng.submit_decode(p, lm["max_new"]) for p in prompts]
    eng.drain()
    cont_s = time.perf_counter() - t0
    n_tokens = sum(len(eng.poll(t)) for t in tickets)
    compiles = eng.compile_count

    # restart baseline: one sequence at a time through the classic cell
    eng2 = Engine()
    eng2.register(lm_decode_cell(lcfg, params, buffers, batch=lm["slots"],
                                 max_len=lm["max_len"], arch="lm"))
    caches = None
    _, caches = eng2.decode(np.array([[1]], np.int32), caches)  # warm
    t0 = time.perf_counter()
    for p in prompts:
        caches, out = None, []
        for i in range(len(p) + lm["max_new"] - 1):
            tok = p[i] if i < len(p) else out[-1]
            logits, caches = eng2.decode(np.array([[tok]], np.int32), caches)
            if i >= len(p) - 1:
                out.append(int(np.argmax(logits[0])))
    restart_s = time.perf_counter() - t0

    return {
        "slots": lm["slots"], "sequences": lm["prompts"],
        "generated_tokens": int(n_tokens),
        "continuous_tok_s": n_tokens / cont_s,
        "restart_tok_s": n_tokens / restart_s,
        "continuous_speedup": restart_s / cont_s,
        "compiles_after_warmup": int(eng.compile_count - compiles),
    }


def run(cfg: dict) -> dict:
    t0 = time.time()
    serve_cfg, params, state, buffers, spec, res = train_packed_dlrm(
        field_vocabs=cfg["field_vocabs"], train_steps=cfg["train_steps"])
    train_s = time.time() - t0

    from repro.models.dlrm import DLRM
    base = build_engine(serve_cfg, params, state, buffers,
                        p99_rows=cfg["p99_rows"], bulk_rows=cfg["bulk_rows"],
                        queue_capacity=cfg["queue_capacity"])
    model_args = ("dlrm", DLRM, serve_cfg, params, state, buffers)

    points = [sweep_point(base, cfg, spec, q, model_args)
              for q in cfg["qps_sweep"]]
    for p in points:
        print(f"[queue_bench] qps={p['offered_qps']:.0f} "
              f"goodput={p['goodput_qps']:.1f} "
              f"p50={p['latency_p50_ms']:.2f}ms p99={p['latency_p99_ms']:.2f}ms "
              f"shed_rate={p['shed_rate']:.2f}")

    tenants = [mix_point(base, cfg, spec, q, model_args)
               for q in cfg["mix_sweep"]]
    for p in tenants:
        lat = p["per_stream"]["latency"]
        blk = p["per_stream"]["bulk"]
        print(f"[queue_bench] mix qps={p['offered_qps']:.0f} "
              f"latency: goodput={lat['goodput_qps']:.1f} shed={lat['shed']} "
              f"| bulk: goodput={blk['goodput_qps']:.1f} shed={blk['shed']}")

    decode = decode_experiment(cfg)
    print(f"[queue_bench] decode: continuous={decode['continuous_tok_s']:.1f} "
          f"tok/s restart={decode['restart_tok_s']:.1f} tok/s "
          f"speedup={decode['continuous_speedup']:.2f}x")

    return {
        "config": {k: (list(v) if isinstance(v, tuple) else v)
                   for k, v in cfg.items() if k != "lm"},
        "env": {"jax": jax.__version__, "backend": jax.default_backend(),
                "device_count": jax.device_count(),
                "platform": platform.platform()},
        "train_s": round(train_s, 2),
        "points": points,
        "tenants": tenants,
        "decode": decode,
        "unix_time": int(time.time()),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny table + short sweep (the CI data point)")
    ap.add_argument("--out", default=None,
                    help="output path (default benchmarks/artifacts/BENCH_queue.json)")
    args = ap.parse_args(argv)

    out_path = args.out or os.path.join("benchmarks", "artifacts",
                                        "BENCH_queue.json")
    result = run(dict(SMOKE if args.smoke else FULL,
                      mode="smoke" if args.smoke else "full"))
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[queue_bench] wrote {out_path}")


if __name__ == "__main__":
    main()
