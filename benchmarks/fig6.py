"""Paper Figure 6: sampled bit-width per frequency group.

Claims: (i) MPE adjusts widths across groups (not uniform), (ii) precision
correlates positively with group frequency, (iii) a redundant-feature tail
collapses to b=0 (feature selection).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import print_csv, run_mpe


def main():
    out, res = run_mpe("dnn", return_result=True)
    gb = res["group_bits"]
    bits = np.asarray([0, 1, 2, 3, 4, 5, 6])[gb]
    g = len(bits)
    deciles = np.array_split(bits, min(10, g))
    rows = []
    for i, dec in enumerate(deciles):
        rows.append([f"fig6/freq_decile_{i}", 0,
                     f"mean_bits={dec.mean():.2f} zeros={np.mean(dec == 0):.2f}"])
        print(rows[-1])
    # headline correlation (group 0 = most frequent)
    ranks = np.arange(g)
    corr = np.corrcoef(ranks, bits)[0, 1]
    rows.append(["fig6/rank_bit_correlation", 0,
                 f"corr={corr:.3f} (negative = frequent features get more bits)"])
    print(rows[-1])
    rows.append(["fig6/avg_bits", 0, f"{out['avg_bits']:.3f}"])
    return rows


if __name__ == "__main__":
    print_csv(main(), ["name", "us_per_call", "derived"])
