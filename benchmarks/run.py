"""Benchmark entry point: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Paper-scale settings take hours
on this CPU container; the default sizes are reduced but preserve every
comparison the paper makes (see benchmarks/common.py). §Roofline numbers come
from the dry-run artifacts (benchmarks/roofline.py) and are appended when
artifacts exist.
"""
from __future__ import annotations

import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="table3|fig3|table4|fig4|fig5|fig6|roofline")
    ap.add_argument("--full", action="store_true",
                    help="all 4 backbones in table3 (slower)")
    args, _ = ap.parse_known_args()

    from benchmarks import fig3, fig4, fig5, fig6, table3, table4
    sections = {
        "table3": lambda: table3.main(full=args.full),
        "fig3": fig3.main,
        "table4": table4.main,
        "fig4": fig4.main,
        "fig5": fig5.main,
        "fig6": fig6.main,
    }
    rows = []
    failures = []
    print("name,us_per_call,derived")
    for name, fn in sections.items():
        if args.only and args.only != name:
            continue
        print(f"# --- {name} ---", flush=True)
        try:
            rows.extend(fn())
        except Exception as e:  # noqa: BLE001 — a section failure is reported,
            import traceback    # not fatal to the remaining tables
            traceback.print_exc()
            failures.append((name, repr(e)))
            print(f"# {name} FAILED: {e}", flush=True)

    if (args.only in (None, "roofline")) and \
            os.path.isdir("benchmarks/artifacts"):
        print("# --- roofline (from dry-run artifacts) ---", flush=True)
        from benchmarks import roofline
        roofline.main()
    if failures:
        print(f"# {len(failures)} section(s) failed: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
