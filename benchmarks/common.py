"""Shared harness for the paper-table benchmarks.

Scaled-down synthetic CTR setting (DESIGN.md §8): the absolute AUCs differ
from the paper (real Criteo/Avazu aren't in the container) but the
*comparisons* — method orderings, compression ratios at matched accuracy,
retraining deltas, transferability — are the reproduction targets.
"""
from __future__ import annotations

import time

import jax

from repro.core import get_compressor
from repro.core.mpe import MPEConfig
from repro.core.pipeline import run_mpe_pipeline
from repro.data.synthetic import CTRSpec, SyntheticCTR
from repro.embeddings.table import FieldSpec
from repro.models.dlrm import DLRMConfig
from repro.train.loop import Trainer
from repro.train.optimizer import adam
from repro.zoo import dlrm_builder

FIELD_VOCABS = (3000, 2000, 1500, 1000, 800, 700)
BATCH = 2048
STEPS = 150
LAM = 3e-5
SEED = 1

_CACHE: dict = {}


def dataset() -> SyntheticCTR:
    if "ds" not in _CACHE:
        _CACHE["ds"] = SyntheticCTR(CTRSpec(field_vocabs=FIELD_VOCABS,
                                            batch_size=BATCH, seed=0))
    return _CACHE["ds"]


def fields():
    return tuple(FieldSpec(f"f{i}", v) for i, v in enumerate(FIELD_VOCABS))


def builder(backbone: str = "dnn", lam: float = LAM):
    ds = dataset()
    key = (backbone, lam)
    if key not in _CACHE:
        base = DLRMConfig(fields=fields(), d_embed=16, mlp_hidden=(64, 32),
                          backbone=backbone)
        _CACHE[key] = dlrm_builder(base, ds.expected_frequencies(), lam=lam,
                                   eval_batches=ds.eval_set(4))
    return _CACHE[key]


METHOD_CFGS = {
    "backbone": ("plain", {}),
    "qr": ("qr", {"k": 2}),
    "pep": ("pep", {}),
    "optfs": ("optfs", {"total_steps": STEPS}),
    "alpt": ("alpt", {"bits": 8}),
    "lsq": ("lsq", {"bits": 6}),
}


def run_baseline(backbone: str, method: str, *, steps: int = STEPS,
                 lam_override: float | None = None, comp_cfg_override=None,
                 return_trained: bool = False):
    """Train a non-MPE method; returns dict(auc, logloss, ratio, seconds).

    ``return_trained`` additionally returns the trained serving state
    ``{params, buffers, state, cfg}`` — what ``baseline_score_cell`` binds,
    so ``compression_bench`` can measure serve p50/p99 per method."""
    name, comp_cfg = METHOD_CFGS[method]
    if comp_cfg_override is not None:
        comp_cfg = comp_cfg_override
    lam = lam_override if lam_override is not None else \
        (1e-4 if method in ("pep", "optfs") else 0.0)
    build = builder(backbone, lam=lam)
    bundle = build(jax.random.PRNGKey(SEED), name, comp_cfg)
    comp = get_compressor(name)
    ds = dataset()

    post = None
    if method == "alpt":
        holder = {"k": jax.random.PRNGKey(SEED + 1)}

        def post(params):
            holder["k"], sub = jax.random.split(holder["k"])
            emb = comp.post_update(params["embedding"], {}, comp_cfg, sub)
            return dict(params, embedding=emb)

    t0 = time.time()
    tr = Trainer(bundle["loss_fn"], bundle["params"], bundle["buffers"],
                 bundle["state"], adam(1e-3), post_update=post)
    tr.run(lambda s: ds.batch(s), steps, log_every=0)
    ev = bundle["eval_fn"](tr.params, bundle["buffers"], tr.state)
    ratio = comp.storage_ratio(tr.params["embedding"],
                               bundle["buffers"]["embedding"], comp_cfg)
    out = {"auc": ev["auc"], "logloss": ev["logloss"], "ratio": ratio,
           "seconds": time.time() - t0}
    if return_trained:
        return out, {"params": tr.params, "buffers": bundle["buffers"],
                     "state": tr.state, "cfg": bundle["cfg"]}
    return out


def run_mpe(backbone: str, *, lam: float = LAM, steps: int = STEPS,
            retrain_mode: str = "mpe", return_result: bool = False):
    build = builder(backbone, lam=lam)
    ds = dataset()
    t0 = time.time()
    res = run_mpe_pipeline(
        build, lambda s: ds.batch(s), key=jax.random.PRNGKey(SEED),
        mpe_cfg=MPEConfig(lam=lam), optimizer=adam(1e-3), search_steps=steps,
        retrain_steps=(0 if retrain_mode == "none" else steps),
        retrain_mode=retrain_mode,
        eval_fn=build(jax.random.PRNGKey(SEED), "plain", {})["eval_fn"],
        log_fn=lambda *a: None)
    out = {"auc": res["eval"]["auc"], "logloss": res["eval"]["logloss"],
           "ratio": res["storage_ratio"], "avg_bits": res["avg_bits"],
           "seconds": time.time() - t0}
    return (out, res) if return_result else out


def print_csv(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
