"""Sharded vs single-device step times + per-collective bytes → BENCH_shard.json.

Runs the four Pallas-kernel paths, the packed score cell and the shard_map
train step on a 1x1 mesh and on real multi-device meshes (1x4, 2x2 by
default — CPU devices are virtualized before jax initializes), records p50
step wall-clock per mesh, and parses the compiled post-SPMD HLO of the
sharded lookup + train step with ``repro.launch.hlo_analysis`` to report the
per-collective byte counts the roofline consumes
(``python -m benchmarks.roofline --shard-bench BENCH_shard.json``).

On shared CI runners the absolute times are noisy (all virtual devices share
one CPU — sharded is *expected* to be slower there); the value of the
artifact is the trajectory and the collective byte counts, which are exact.

    PYTHONPATH=src python benchmarks/shard_bench.py --smoke
    PYTHONPATH=src python benchmarks/shard_bench.py --devices 4 --out BENCH_shard.json
"""
from __future__ import annotations

import argparse
import os
import sys


def _early_devices() -> int:
    """--devices must take effect before jax initializes its backend."""
    for i, a in enumerate(sys.argv):
        if a == "--devices" and i + 1 < len(sys.argv):
            return int(sys.argv[i + 1])
        if a.startswith("--devices="):
            return int(a.split("=", 1)[1])
    return 4


_N_DEV = _early_devices()
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           f" --xla_force_host_platform_device_count={_N_DEV}"
                           ).strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import json  # noqa: E402
import platform  # noqa: E402
import time  # noqa: E402

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import quantizer  # noqa: E402
from repro.core.inference import build_packed_table  # noqa: E402
from repro.core.mpe import MPEConfig  # noqa: E402
from repro.dist import shard  # noqa: E402
from repro.dist.mesh import host_mesh, make_device_mesh, use_mesh  # noqa: E402
from repro.launch.hlo_analysis import analyze  # noqa: E402

SMOKE = dict(n=2000, d=16, batch=256, bag_rows=1000, bag_batch=64, bag_l=8,
             attn=(2, 64, 4, 32), qat_rows=1024, iters=20,
             train_vocabs=(300, 200), train_batch=256, train_iters=10)
FULL = dict(n=20000, d=32, batch=1024, bag_rows=10000, bag_batch=256, bag_l=16,
            attn=(4, 128, 8, 64), qat_rows=8192, iters=50,
            train_vocabs=(2000, 1500), train_batch=1024, train_iters=20)


def _meshes():
    n = jax.device_count()
    out = [("1x1", host_mesh(n_data=1, n_model=1))]
    if n >= 4:
        out += [("1x4", make_device_mesh((1, 4), ("data", "model"))),
                ("2x2", make_device_mesh((2, 2), ("data", "model")))]
    elif n > 1:
        out += [(f"1x{n}", make_device_mesh((1, n), ("data", "model")))]
    return out


def _time_ms(fn, args, iters):
    out = fn(*args)  # compile + warm
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e3)
    return round(float(np.percentile(times, 50)), 4)


def _collectives(jitted, *args) -> dict:
    """Per-collective byte counts of a compiled callable (loop-aware,
    per-device — see hlo_analysis)."""
    lowered = jitted.lower(*args)
    coll = analyze(lowered.compile().as_text())["collectives_per_device"]
    return {k: (v if isinstance(v, (int, float)) else dict(v))
            for k, v in coll.items()}


def bench_kernels(cfg: dict) -> dict:
    rng = np.random.default_rng(0)
    mcfg = MPEConfig()
    n, d = cfg["n"], cfg["d"]
    emb = rng.normal(size=(n, d)).astype(np.float32)
    fbits = rng.integers(0, len(mcfg.bits), size=n).astype(np.int32)
    alpha = (np.abs(rng.normal(size=len(mcfg.bits))) * 0.1 + 0.01).astype(np.float32)
    beta = (rng.normal(size=d) * 0.01).astype(np.float32)
    table, meta = build_packed_table(emb, fbits, alpha, beta, mcfg)
    ids = jnp.asarray(rng.integers(0, n, size=(cfg["batch"],)), jnp.int32)

    bag_tab = jnp.asarray(rng.normal(0, 1, (cfg["bag_rows"], d)), jnp.float32)
    bag_ids = jnp.asarray(rng.integers(0, cfg["bag_rows"],
                                       (cfg["bag_batch"], cfg["bag_l"])))
    bag_mask = jnp.ones((cfg["bag_batch"], cfg["bag_l"]), bool)

    b_, s, h, hd = cfg["attn"]
    q = jnp.asarray(rng.normal(0, 1, (b_, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b_, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b_, s, h, hd)), jnp.float32)

    bits = mcfg.bits
    rows = jnp.asarray(rng.normal(0, 3e-3, (cfg["qat_rows"], d)), jnp.float32)
    probs = jax.nn.softmax(
        jnp.asarray(rng.normal(0, 1, (cfg["qat_rows"], len(bits))),
                    jnp.float32), -1)
    qa = jnp.asarray([quantizer.init_alpha(3e-3, b) for b in bits])
    qb = jnp.asarray(rng.normal(0, 1e-4, (d,)), jnp.float32)

    kernels = {
        "mpe_lookup": (lambda t, i: shard.sharded_packed_lookup(t, meta, i),
                       (table, ids)),
        "embedding_bag": (lambda t, i, m: shard.sharded_embedding_bag(t, i, m),
                          (bag_tab, bag_ids, bag_mask)),
        "flash_attention": (
            lambda a, b2, c: shard.sharded_flash_attention(a, b2, c),
            (q, k, v)),
        "mpe_qat": (
            lambda r, p, a, b2: shard.sharded_mixed_expectation(r, p, a, b2,
                                                                bits),
            (rows, probs, qa, qb)),
    }

    out = {}
    for mesh_name, mesh in _meshes():
        with use_mesh(mesh):
            entry = {}
            for kname, (fn, args) in kernels.items():
                jitted = jax.jit(fn)
                rec = {"p50_ms": _time_ms(jitted, args, cfg["iters"])}
                if mesh.size > 1 and kname == "mpe_lookup":
                    rec["collectives"] = _collectives(jitted, *args)
                entry[kname] = rec
            out[mesh_name] = entry
        print(f"[shard_bench] kernels {mesh_name}: " +
              " ".join(f"{k}={v['p50_ms']}ms" for k, v in out[mesh_name].items()))
    return out


CROSS_BITS = {"b4": 4, "b8": 8, "b16": 16}
CROSS = dict(n=4096, d=16, batch=256)  # fixed: the crossover rows are gated


def bench_crossover(cfg: dict) -> dict:
    """psum-vs-a2a sweep: model-axis width × bucket capacity × bit-width.

    Every row records the measured p50 of both comms paths, the exact
    per-collective byte counts from the compiled HLO, and the deterministic
    routing counters of ``shard.lookup_route_stats`` — the counters, byte
    totals, compile counts and the ``a2a_fewer_bytes`` verdict are pure
    functions of this fixed config (``CROSS``, independent of --smoke), so
    ``bench-gate`` exact-diffs them against the checked-in baseline. The
    crossover itself: at d=16 a packed row is W=ceil(b·16/32) words, so a2a
    ships ~4·(ids + 2·W·batch) bytes against psum's 64·batch — below b≈16
    the id shuffle wins, above it the dense partial merge does.
    """
    rng = np.random.default_rng(7)
    n, d, batch = CROSS["n"], CROSS["d"], CROSS["batch"]
    emb = rng.normal(size=(n, d)).astype(np.float32)
    beta = (rng.normal(size=d) * 0.01).astype(np.float32)
    ids = jnp.asarray(rng.integers(0, n, size=(batch,)), jnp.int32)

    def _compiles(jitted) -> int:
        try:
            return int(jitted._cache_size())
        except Exception:  # noqa: BLE001 — internal API; absence → "compiled once"
            return 1

    out = {}
    for mp in (2, 4):
        if mp > jax.device_count():
            continue
        mesh = make_device_mesh((1, mp), ("data", "model"))
        rows = {}
        with use_mesh(mesh):
            for bname, b in CROSS_BITS.items():
                mcfg = MPEConfig(bits=(0, b))
                fbits = np.ones(n, np.int32)  # every feature at width b
                alpha = np.asarray(
                    [quantizer.init_alpha(0.1, bb) for bb in mcfg.bits],
                    np.float32)
                table, meta = build_packed_table(emb, fbits, alpha, beta, mcfg)
                slice_len = -(-batch // mp)
                caps = {"full": None, "half": max(1, slice_len // 2),
                        "quarter": max(1, slice_len // 4)}
                jp = jax.jit(lambda t, i, _m=meta:
                             shard.sharded_packed_lookup(t, _m, i))
                psum_ms = _time_ms(jp, (table, ids), cfg["iters"])
                pcoll = _collectives(jp, table, ids)
                want = np.asarray(jp(table, ids))
                per_bits = {}
                for cname, cap in caps.items():
                    ja = jax.jit(lambda t, i, _m=meta, _c=cap:
                                 shard.sharded_packed_lookup(
                                     t, _m, i, lookup_comms="a2a",
                                     bucket_capacity=_c))
                    a2a_ms = _time_ms(ja, (table, ids), cfg["iters"])
                    acoll = _collectives(ja, table, ids)
                    got = np.asarray(ja(table, ids))
                    rec = dict(shard.lookup_route_stats(
                        table, meta, ids, n_shards=mp, bucket_capacity=cap))
                    rec.update(
                        bit_width=b,
                        psum_p50_ms=psum_ms, a2a_p50_ms=a2a_ms,
                        psum_collectives=pcoll, a2a_collectives=acoll,
                        psum_collective_bytes=pcoll["total_bytes"],
                        a2a_collective_bytes=acoll["total_bytes"],
                        a2a_fewer_bytes=bool(acoll["total_bytes"]
                                             < pcoll["total_bytes"]),
                        bit_exact=bool(np.array_equal(want, got)),
                        psum_compiles=_compiles(jp),
                        a2a_compiles=_compiles(ja))
                    per_bits[cname] = rec
                rows[bname] = per_bits
                full = per_bits["full"]
                print(f"[shard_bench] crossover 1x{mp} {bname}: "
                      f"psum={full['psum_collective_bytes']:.0f}B "
                      f"a2a={full['a2a_collective_bytes']:.0f}B "
                      f"a2a_fewer={full['a2a_fewer_bytes']} "
                      f"exact={full['bit_exact']}")
        out[f"1x{mp}"] = rows
    return out


def bench_train_step(cfg: dict) -> dict:
    from repro.data.synthetic import CTRSpec, SyntheticCTR
    from repro.embeddings.table import FieldSpec
    from repro.models.dlrm import DLRMConfig
    from repro.train.loop import Trainer
    from repro.train.optimizer import adam
    from repro.zoo import dlrm_builder

    spec = CTRSpec(field_vocabs=cfg["train_vocabs"],
                   batch_size=cfg["train_batch"], seed=0)
    ds = SyntheticCTR(spec)
    fields = tuple(FieldSpec(f"f{i}", v)
                   for i, v in enumerate(spec.field_vocabs))
    base = DLRMConfig(fields=fields, d_embed=16, mlp_hidden=(64, 32),
                      backbone="dnn", use_batchnorm=False)
    build = dlrm_builder(base, ds.expected_frequencies())

    out = {}
    for mesh_name, mesh in _meshes():
        bundle = build(jax.random.PRNGKey(0), "plain", {})
        tr = Trainer(bundle["loss_fn"], bundle["params"], bundle["buffers"],
                     bundle["state"], adam(1e-3),
                     mesh=None if mesh.size <= 1 else mesh)
        t0 = time.time()
        tr.run(lambda s: ds.batch(s), cfg["train_iters"], log_every=0)
        ms = (time.time() - t0) / cfg["train_iters"] * 1e3
        rec = {"ms_per_step": round(ms, 3)}
        if mesh.size > 1:
            from repro.dist.shard import sharded_value_and_grad
            vag = sharded_value_and_grad(bundle["loss_fn"], mesh)
            batch = {k2: jnp.asarray(v2) for k2, v2 in ds.batch(0).items()}
            jitted = jax.jit(lambda p, bu, st, ba: vag(p, bu, st, ba, step=0))
            rec["collectives"] = _collectives(
                jitted, bundle["params"], bundle["buffers"], bundle["state"],
                batch)
        out[mesh_name] = rec
        print(f"[shard_bench] train {mesh_name}: {rec['ms_per_step']}ms/step")
    return out


def bench_serve_cell(cfg: dict) -> dict:
    from repro.data.synthetic import SyntheticCTR
    from repro.launch.serve import build_engine, train_packed_dlrm

    serve_cfg, params, state, buffers, spec, res = train_packed_dlrm(
        field_vocabs=cfg["train_vocabs"] + (500,), train_steps=20,
        train_batch=256, d_embed=16, mlp_hidden=(32,))
    req = SyntheticCTR(spec._replace(batch_size=128)).batch(10_000)["ids"]

    out = {}
    for mesh_name, mesh in _meshes():
        engine = build_engine(serve_cfg, params, state, buffers, p99_rows=128,
                              bulk_rows=512, lookup_split=False, mesh=mesh)
        engine.score(req)  # warm
        times = []
        for step in range(cfg["iters"]):
            t0 = time.perf_counter()
            engine.score(req)
            times.append((time.perf_counter() - t0) * 1e3)
        out[mesh_name] = {
            "score_p50_ms": round(float(np.percentile(times, 50)), 3),
            "compiles": engine.compile_count,
        }
        print(f"[shard_bench] serve {mesh_name}: "
              f"{out[mesh_name]['score_p50_ms']}ms "
              f"(compiles={engine.compile_count})")
    return out


def run(cfg: dict, crossover_only: bool = False) -> dict:
    out = {
        "config": {k: (list(v) if isinstance(v, tuple) else v)
                   for k, v in cfg.items()},
        "env": {"jax": jax.__version__, "backend": jax.default_backend(),
                "device_count": jax.device_count(),
                "platform": platform.platform()},
    }
    if not crossover_only:
        out["kernels"] = bench_kernels(cfg)
        out["train"] = bench_train_step(cfg)
        out["serve"] = bench_serve_cell(cfg)
    out["crossover"] = bench_crossover(cfg)
    out["unix_time"] = int(time.time())
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes (the CI data point)")
    ap.add_argument("--devices", type=int, default=4,
                    help="virtual CPU device count (consumed before jax "
                         "initializes)")
    ap.add_argument("--crossover-only", action="store_true",
                    help="run just the psum-vs-a2a crossover sweep (the "
                         "bench-gate data point; its counters are "
                         "independent of --smoke)")
    ap.add_argument("--out", default=None,
                    help="output path (default benchmarks/artifacts/"
                         "BENCH_shard.json)")
    args = ap.parse_args(argv)

    out_path = args.out or os.path.join("benchmarks", "artifacts",
                                        "BENCH_shard.json")
    result = run(dict(SMOKE if args.smoke else FULL,
                      mode="smoke" if args.smoke else "full"),
                 crossover_only=args.crossover_only)
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[shard_bench] wrote {out_path}")


if __name__ == "__main__":
    main()
