#!/usr/bin/env bash
# Tier-1 verification (ROADMAP.md): full test run plus a collection-only
# smoke so import-graph breakage (a module importing a symbol that doesn't
# exist yet) fails fast instead of hiding behind collection errors.
#
# Usage: scripts/verify.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== collection smoke (zero import errors required) =="
python -m pytest --collect-only -q >/dev/null

echo "== tier-1 tests =="
python -m pytest -x -q "$@"
