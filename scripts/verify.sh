#!/usr/bin/env bash
# Tier-1 verification (ROADMAP.md): full test run plus a collection-only
# smoke so import-graph breakage (a module importing a symbol that doesn't
# exist yet) fails fast instead of hiding behind collection errors.
#
# Usage: scripts/verify.sh [--static] [extra pytest args...]
#   --static   additionally run the static contract gate
#              (scripts/staticcheck.py — the blocking `staticcheck` CI job)
#              before the test suite, so the whole gate is reproducible
#              locally with one command.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

RUN_STATIC=0
if [[ "${1:-}" == "--static" ]]; then
  RUN_STATIC=1
  shift
fi

if [[ "$RUN_STATIC" == "1" ]]; then
  echo "== staticcheck (repro.analysis contract gate) =="
  python scripts/staticcheck.py
fi

echo "== collection smoke (zero import errors required) =="
python -m pytest --collect-only -q >/dev/null

echo "== tier-1 tests =="
python -m pytest -x -q "$@"
