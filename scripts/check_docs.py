#!/usr/bin/env python
"""Docs link + command + docstring check — the blocking CI `docs` job.

Validates that intra-repo references in the documentation actually exist:

  1. every relative markdown link ``[text](target)`` in README.md, docs/ and
     benchmarks/README.md resolves to a real file (anchors stripped; http/
     mailto links skipped);
  2. every backticked repo path (`src/...`, `scripts/verify.sh`, ...) with a
     source-file extension exists — generated artifacts (``BENCH_*.json``,
     paths under ``benchmarks/artifacts/``) are exempt;
  3. every command in a fenced ```bash block resolves: a ``python -m
     repro.x.y`` / ``python -m benchmarks.x`` module must map to a real
     source file, and any ``scripts/*.py``-style path named in a command
     must exist (the doc-rot class the link checker misses); additionally,
     every ``--flag`` the command passes must appear among the target
     module's ``add_argument`` calls (pure AST — renaming a CLI knob
     without updating its documented examples fails the docs job);
  4. with ``--docstrings``: a pure-AST pass (no imports — the docs CI job
     installs no jax) asserting every name exported from the public
     ``repro.cache`` and ``repro.analysis`` ``__init__``s and every public
     top-level name in ``repro.serve.repack`` carries a docstring.

Exit code 0 when clean, 1 with a per-reference report otherwise. Run from
anywhere: paths resolve against the repo root (this file's parent's parent).

    python scripts/check_docs.py --docstrings
"""
from __future__ import annotations

import argparse
import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOC_GLOBS = ["README.md", "docs/**/*.md", "benchmarks/README.md"]
# markdown links, excluding images' URL part being external
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# `backticked` repo-relative paths with a source-ish extension
PATH_RE = re.compile(
    r"`([A-Za-z0-9_.\-/]+\.(?:py|md|sh|yml|yaml|toml|json|txt))`")
GENERATED = re.compile(r"(^|/)BENCH_[^/]*\.json$|^benchmarks/artifacts/|"
                       r"^out\.json$")

# fenced command blocks + the two command shapes we can statically resolve
BASH_RE = re.compile(r"```(?:bash|sh|console)[^\n]*\n(.*?)```", re.S)
MOD_RE = re.compile(r"python[0-9.]*\s+-m\s+([A-Za-z0-9_.]+)")
CMD_PATH_RE = re.compile(
    r"(?<![\w/.\-])((?:scripts|benchmarks|src|tests|docs)/[\w./\-]+"
    r"\.(?:py|sh|md))")
# top-level packages the repo owns — `python -m pytest` etc. are skipped
LOCAL_PKGS = {"repro", "benchmarks", "scripts", "tests"}

# --docstrings targets: public package __init__s (every exported name) and
# the repack module (every public top-level name)
DOCSTRING_TARGETS = {
    "repro.cache": "src/repro/cache/__init__.py",
    "repro.analysis": "src/repro/analysis/__init__.py",
    "repro.serve.repack": "src/repro/serve/repack.py",
}


def doc_files() -> list[Path]:
    files: list[Path] = []
    for pattern in DOC_GLOBS:
        files.extend(sorted(REPO.glob(pattern)))
    # dedupe while keeping order
    seen, out = set(), []
    for f in files:
        if f not in seen:
            seen.add(f)
            out.append(f)
    return out


def resolve(md_file: Path, target: str) -> bool:
    """A target exists if it resolves relative to the md file's directory or
    to the repo root (docs use both conventions)."""
    return ((md_file.parent / target).exists()
            or (REPO / target).exists())


def module_file(mod: str) -> Path | None:
    """Map a dotted module to its source file under src/ or the repo root."""
    rel = Path(*mod.split("."))
    for root in (REPO / "src", REPO):
        for cand in ((root / rel).with_suffix(".py"),
                     root / rel / "__init__.py"):
            if cand.exists():
                return cand
    return None


FLAG_RE = re.compile(r"(?<![\w-])(--[A-Za-z][A-Za-z0-9-]*)")


def module_flags(src: Path) -> set[str] | None:
    """All ``--flags`` a module's argparse surface accepts (AST scan of
    ``add_argument`` string literals). ``None`` when the module has no
    ``add_argument`` calls — flag checking doesn't apply to it."""
    tree = ast.parse(src.read_text(encoding="utf-8"))
    flags, found = {"--help"}, False
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_argument"):
            found = True
            for a in node.args:
                if isinstance(a, ast.Constant) and isinstance(a.value, str) \
                        and a.value.startswith("--"):
                    flags.add(a.value)
    return flags if found else None


def _logical_lines(block: str):
    """Join backslash-continued lines — documented commands wrap."""
    out, acc = [], ""
    for line in block.splitlines():
        line = line.split("#", 1)[0]
        if line.rstrip().endswith("\\"):
            acc += line.rstrip()[:-1] + " "
        else:
            out.append(acc + line)
            acc = ""
    if acc:
        out.append(acc)
    return out


def check_bash_blocks(md: Path, text: str, rel) -> tuple[int, list[str]]:
    """Resolve `python -m` modules, repo-path arguments and ``--flag``
    spellings inside fenced command blocks."""
    errors, n_refs = [], 0
    for block in BASH_RE.finditer(text):
        for line in _logical_lines(block.group(1)):
            target_src = None      # the file whose argparse governs `line`
            for m in MOD_RE.finditer(line):
                mod = m.group(1)
                if mod.split(".", 1)[0] not in LOCAL_PKGS:
                    continue
                n_refs += 1
                src = module_file(mod)
                if src is None:
                    errors.append(f"{rel}: bash block names module "
                                  f"`{mod}` which does not resolve")
                else:
                    target_src = src
            for m in CMD_PATH_RE.finditer(line):
                target = m.group(1)
                if GENERATED.search(target):
                    continue
                n_refs += 1
                if not resolve(md, target):
                    errors.append(f"{rel}: bash block references missing "
                                  f"path -> {target}")
                elif target.endswith(".py") and re.search(
                        rf"python[0-9.]*\s+{re.escape(target)}", line):
                    target_src = REPO / target
            if target_src is None:
                continue
            known = module_flags(target_src)
            if known is None:
                continue
            for flag in FLAG_RE.findall(line):
                n_refs += 1
                if flag.split("=", 1)[0] not in known:
                    errors.append(
                        f"{rel}: bash block passes `{flag}` but "
                        f"{target_src.relative_to(REPO)} defines no such "
                        "flag")
    return n_refs, errors


def _is_def(node) -> bool:
    return isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef))


def check_docstrings() -> list[str]:
    """AST-only docstring audit over the public API targets (no imports)."""
    errors: list[str] = []
    for mod, rel in DOCSTRING_TARGETS.items():
        path = REPO / rel
        if not path.exists():
            errors.append(f"{rel}: docstring target missing")
            continue
        tree = ast.parse(path.read_text(encoding="utf-8"))
        if not ast.get_docstring(tree):
            errors.append(f"{rel}: missing module docstring")
        if rel.endswith("__init__.py"):
            errors.extend(_check_exports(mod, rel, tree))
        else:
            for node in tree.body:
                if _is_def(node) and not node.name.startswith("_") \
                        and not ast.get_docstring(node):
                    errors.append(f"{rel}: public `{node.name}` has no "
                                  f"docstring")
    return errors


def _check_exports(mod: str, rel: str, tree: ast.Module) -> list[str]:
    """Every name in a package ``__init__``'s ``__all__`` must carry a
    docstring at its definition site (re-exports are followed one hop)."""
    errors: list[str] = []
    exported: list[str] = []
    imports: dict[str, tuple[str, str]] = {}
    local: dict[str, ast.AST] = {}
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__all__":
                    exported = list(ast.literal_eval(node.value))
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                imports[a.asname or a.name] = (node.module, a.name)
        elif _is_def(node):
            local[node.name] = node
    if not exported:
        return [f"{rel}: no __all__ — the public surface is implicit"]
    for name in exported:
        node, where = local.get(name), rel
        if node is None and name in imports:
            src_mod, orig = imports[name]
            src = module_file(src_mod)
            if src is None:
                errors.append(f"{rel}: exported `{name}` imports from "
                              f"unresolvable module {src_mod}")
                continue
            node, src = _find_def(src, orig)
            where = str(src.relative_to(REPO)) if src is not None else rel
        if node is None:
            errors.append(f"{rel}: cannot locate definition of exported "
                          f"`{name}`")
        elif not ast.get_docstring(node):
            errors.append(f"{where}: exported `{name}` has no docstring")
    return errors


def _find_def(src: Path, name: str, depth: int = 5):
    """Locate a def/class by name in ``src``, following chained
    ``from x import y`` re-exports up to ``depth`` hops."""
    if depth == 0:
        return None, None
    tree = ast.parse(src.read_text(encoding="utf-8"))
    for n in tree.body:
        if _is_def(n) and n.name == name:
            return n, src
    for n in tree.body:
        if isinstance(n, ast.ImportFrom) and n.module:
            for a in n.names:
                if (a.asname or a.name) == name:
                    nxt = module_file(n.module)
                    if nxt is not None:
                        return _find_def(nxt, a.name, depth - 1)
    return None, None


def check(docstrings: bool = False) -> int:
    files = doc_files()
    if not files:
        print("check_docs: no documentation files found", file=sys.stderr)
        return 1
    errors = []
    n_refs = 0
    for md in files:
        text = md.read_text(encoding="utf-8")
        rel = md.relative_to(REPO)
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            target = target.split("#", 1)[0]
            if not target:
                continue
            n_refs += 1
            if not resolve(md, target):
                errors.append(f"{rel}: broken link -> {m.group(1)}")
        for m in PATH_RE.finditer(text):
            target = m.group(1)
            if GENERATED.search(target) or "/" not in target:
                continue
            n_refs += 1
            if not resolve(md, target):
                errors.append(f"{rel}: referenced path missing -> {target}")
        n_cmd, cmd_errors = check_bash_blocks(md, text, rel)
        n_refs += n_cmd
        errors.extend(cmd_errors)
    n_doc = 0
    if docstrings:
        doc_errors = check_docstrings()
        n_doc = len(doc_errors)
        errors.extend(doc_errors)
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    print(f"check_docs: {len(files)} files, {n_refs} intra-repo references"
          + (", docstring audit on" if docstrings else "")
          + f", {len(errors)} broken")
    return 1 if errors else 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--docstrings", action="store_true",
                    help="also audit public-API docstrings (pure AST)")
    args = ap.parse_args()
    sys.exit(check(docstrings=args.docstrings))
