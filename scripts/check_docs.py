#!/usr/bin/env python
"""Docs link check — the blocking CI `docs` job.

Validates that intra-repo references in the documentation actually exist:

  1. every relative markdown link ``[text](target)`` in README.md, docs/ and
     benchmarks/README.md resolves to a real file (anchors stripped; http/
     mailto links skipped);
  2. every backticked repo path (`src/...`, `scripts/verify.sh`, ...) with a
     source-file extension exists — generated artifacts (``BENCH_*.json``,
     paths under ``benchmarks/artifacts/``) are exempt.

Exit code 0 when clean, 1 with a per-reference report otherwise. Run from
anywhere: paths resolve against the repo root (this file's parent's parent).

    python scripts/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOC_GLOBS = ["README.md", "docs/**/*.md", "benchmarks/README.md"]
# markdown links, excluding images' URL part being external
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# `backticked` repo-relative paths with a source-ish extension
PATH_RE = re.compile(
    r"`([A-Za-z0-9_.\-/]+\.(?:py|md|sh|yml|yaml|toml|json|txt))`")
GENERATED = re.compile(r"(^|/)BENCH_[^/]*\.json$|^benchmarks/artifacts/|"
                       r"^out\.json$")


def doc_files() -> list[Path]:
    files: list[Path] = []
    for pattern in DOC_GLOBS:
        files.extend(sorted(REPO.glob(pattern)))
    # dedupe while keeping order
    seen, out = set(), []
    for f in files:
        if f not in seen:
            seen.add(f)
            out.append(f)
    return out


def resolve(md_file: Path, target: str) -> bool:
    """A target exists if it resolves relative to the md file's directory or
    to the repo root (docs use both conventions)."""
    return ((md_file.parent / target).exists()
            or (REPO / target).exists())


def check() -> int:
    files = doc_files()
    if not files:
        print("check_docs: no documentation files found", file=sys.stderr)
        return 1
    errors = []
    n_refs = 0
    for md in files:
        text = md.read_text(encoding="utf-8")
        rel = md.relative_to(REPO)
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            target = target.split("#", 1)[0]
            if not target:
                continue
            n_refs += 1
            if not resolve(md, target):
                errors.append(f"{rel}: broken link -> {m.group(1)}")
        for m in PATH_RE.finditer(text):
            target = m.group(1)
            if GENERATED.search(target) or "/" not in target:
                continue
            n_refs += 1
            if not resolve(md, target):
                errors.append(f"{rel}: referenced path missing -> {target}")
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    print(f"check_docs: {len(files)} files, {n_refs} intra-repo references, "
          f"{len(errors)} broken")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(check())
