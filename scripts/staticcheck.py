#!/usr/bin/env python
"""Static contract checker CLI — the blocking ``staticcheck`` CI gate.

Runs ``repro.analysis`` over the repo: AST lint (RL4xx) plus the
trace-level passes (PF/SC/RC/BC) over the tiny standard cell corpus,
compiled on a virtual 2x2 mesh so the shard_map and collective paths are
exercised without accelerators.

Exit codes: 0 clean, 1 findings, 2 internal error.

Usage:
    python scripts/staticcheck.py                 # the whole gate
    python scripts/staticcheck.py --lint-only     # AST rules only (fast)
    python scripts/staticcheck.py --trace-only    # jaxpr/HLO passes only
    python scripts/staticcheck.py --select PF,SC2 # filter by code prefix
    python scripts/staticcheck.py --update-budgets  # refresh budgets.json
    python scripts/staticcheck.py --devices 1     # skip the virtual mesh
"""
from __future__ import annotations

import argparse
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _force_devices(n: int) -> None:
    # must land before jax (transitively) imports — keep this ahead of any
    # repro.analysis import
    if n > 1 and "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count={n}".strip())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--lint-only", action="store_true",
                    help="AST rules only; no jax import, no tracing")
    ap.add_argument("--trace-only", action="store_true",
                    help="jaxpr/HLO passes only; skip the AST lint")
    ap.add_argument("--select", default=None, metavar="PREFIXES",
                    help="comma-separated rule-code prefixes to keep "
                         "(e.g. 'PF,SC2')")
    ap.add_argument("--update-budgets", action="store_true",
                    help="rewrite src/repro/analysis/budgets.json from "
                         "measured collective bytes (+25%% headroom) "
                         "instead of gating on it")
    ap.add_argument("--devices", type=int, default=4,
                    help="virtual CPU device count for the corpus mesh "
                         "(default 4 -> 2x2; 1 skips the flag)")
    args = ap.parse_args(argv)

    if args.lint_only:
        sys.path.insert(0, os.path.join(REPO_ROOT, "src"))
        from repro.analysis.lint import lint_tree
        findings = lint_tree(REPO_ROOT)
        for f in findings:
            print(f.render())
        print(f"{len(findings)} lint finding(s)")
        return 1 if findings else 0

    _force_devices(args.devices)
    sys.path.insert(0, os.path.join(REPO_ROOT, "src"))
    import repro.analysis as A
    from repro.analysis.budgets import budget_entry, save_budgets

    report = A.run(REPO_ROOT, lint=not args.trace_only)

    if args.update_budgets:
        budgets = {name: budget_entry(measured)
                   for name, measured in sorted(report.measured.items())}
        save_budgets(budgets)
        # stale BC findings were gated on the old file; drop them
        report.findings = [f for f in report.findings
                           if not f.code.startswith("BC")]
        print(f"budgets.json updated: {len(budgets)} cell(s)")

    if args.select:
        prefixes = tuple(p.strip() for p in args.select.split(",")
                         if p.strip())
        report.findings = [f for f in report.findings
                           if f.code.startswith(prefixes)]

    print(report.render())
    return 1 if report.findings else 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except Exception:
        import traceback
        traceback.print_exc()
        sys.exit(2)
