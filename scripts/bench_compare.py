#!/usr/bin/env python
"""Diff fresh BENCH_*.json smoke numbers against the checked-in baselines.

Two modes:

**Advisory (default).** The bench-smoke CI job runs the smoke benchmarks,
then this script compares every numeric metric against
``benchmarks/baselines/BENCH_*.json`` and writes a markdown delta table to
``$GITHUB_STEP_SUMMARY`` (and stdout). The job stays ``continue-on-error``
— shared-runner noise must not veto a correct change — but regressions
become *visible* in the PR summary instead of silently shipping.

Comparable metrics are the flattened numeric leaves of each artifact, minus
environment-dependent keys (timestamps, one-off setup costs, env/config
records). Latency-ish keys (``*_ms``, ``p50``/``p99``, ``ms_per_step``) get
a ⚠ marker above +20%; throughput-ish keys (``goodput``, ``*_tok_s``,
``*_speedup``, ``occupancy`` — higher is better, BENCH_queue) get one below
-20% — advisory only on shared runners.

    python scripts/bench_compare.py --fresh . --baseline benchmarks/baselines
Advisory exit code is always 0: visibility, not a gate.

**Gate (``--gate benchmarks/gate_metrics.json``).** The blocking bench-gate
CI job checks only the metrics named by the allowlist file — metrics that
are *deterministic by construction* (the ``TickClock`` open-loop replay:
hit rates, bytes moved, shed counts, promotion/writeback counters, compile
counts — never wall-clock). Any mismatch vs the checked-in baseline exits
non-zero; so does a stale allowlist (pattern matching nothing, metric
missing from either side) or an allowlist pattern that reaches a
wall-clock-looking key. Intended behaviour changes regenerate the baseline
in the same PR — that diff *is* the review surface.

    python scripts/bench_compare.py --fresh . --baseline benchmarks/baselines \\
        --gate benchmarks/gate_metrics.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

SKIP = re.compile(r"(^|\.)(unix_time|train_s|register_s|seconds|compile|"
                  r"compiles|env|config)(\.|$)")
LATENCY = re.compile(r"(_ms|p50|p99|ms_per_step)($|\.)")
# higher-is-better metrics (BENCH_queue goodput, BENCH_compression accuracy):
# warn on *decreases* instead
THROUGHPUT = re.compile(r"(goodput|_tok_s|_speedup|occupancy|auc)($|\.|_)")
WARN_PCT = 20.0


def flatten(node, prefix="") -> dict:
    out = {}
    if isinstance(node, dict):
        for k, v in node.items():
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            out.update(flatten(v, f"{prefix}{i}."))
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        out[prefix[:-1]] = float(node)
    return out


def compare(fresh: dict, base: dict) -> list[tuple]:
    f, b = flatten(fresh), flatten(base)
    rows = []
    for key in sorted(set(f) & set(b)):
        if SKIP.search(key):
            continue
        new, old = f[key], b[key]
        if old == 0:
            delta = 0.0 if new == 0 else float("inf")
        else:
            delta = (new - old) / abs(old) * 100.0
        rows.append((key, old, new, delta))
    return rows


def fmt_val(x: float) -> str:
    return f"{x:.4g}"


def render(name: str, rows: list[tuple], top: int = 12) -> str:
    lines = [f"### {name}", "",
             "| metric | baseline | fresh | Δ% | |",
             "|---|---:|---:|---:|---|"]
    ranked = sorted(rows, key=lambda r: -abs(r[3]))[:top]
    for key, old, new, delta in ranked:
        warn = ""
        if LATENCY.search(key) and delta > WARN_PCT:
            warn = "⚠"
        elif THROUGHPUT.search(key) and delta < -WARN_PCT:
            warn = "⚠"
        d = "inf" if delta == float("inf") else f"{delta:+.1f}"
        lines.append(f"| `{key}` | {fmt_val(old)} | {fmt_val(new)} | {d} | "
                     f"{warn} |")
    n_more = len(rows) - len(ranked)
    if n_more > 0:
        lines.append(f"\n({n_more} more metrics within smaller deltas)")
    return "\n".join(lines) + "\n"


# gate mode: allowlisted metrics must never look wall-clock — determinism is
# the whole contract (a timing metric here would flake the blocking job)
WALLCLOCK = re.compile(r"(_ms|ms_per_step|_tok_s|p50|p99|unix_time|"
                       r"_s|seconds|time)($|\.)")


def gate_check(fresh_dir: str, baseline_dir: str, gate_path: str
               ) -> tuple[list[str], int]:
    """Check every allowlisted metric for exact (or ``tol_pct``) agreement.

    Returns ``(failures, n_checked)`` — empty failures means the gate
    passes. Unlike the advisory compare, nothing is SKIPped here: compile
    counts are first-class gate metrics.
    """
    with open(gate_path) as fh:
        cfg = json.load(fh)
    failures, checked = [], 0
    for name, spec in cfg["files"].items():
        fresh_path = os.path.join(fresh_dir, name)
        base_path = os.path.join(baseline_dir, name)
        if not os.path.exists(fresh_path):
            failures.append(f"{name}: fresh artifact missing from "
                            f"{fresh_dir!r} — did the benchmark run?")
            continue
        if not os.path.exists(base_path):
            failures.append(f"{name}: no checked-in baseline at "
                            f"{base_path!r}")
            continue
        with open(fresh_path) as fh:
            f = flatten(json.load(fh))
        with open(base_path) as fh:
            b = flatten(json.load(fh))
        for rule in spec["rules"]:
            pat = re.compile(rule["pattern"])
            tol = float(rule.get("tol_pct", 0.0))
            keys = sorted(k for k in set(f) | set(b) if pat.search(k))
            if not keys:
                failures.append(
                    f"{name}: allowlist pattern {rule['pattern']!r} matches "
                    "no metrics — stale gate config")
                continue
            for k in keys:
                if WALLCLOCK.search(k):
                    failures.append(
                        f"{name}: {k} is matched by the allowlist but looks "
                        "wall-clock — the gate takes deterministic metrics "
                        "only")
                    continue
                if k not in f:
                    failures.append(f"{name}: {k} missing from the fresh "
                                    "run")
                    continue
                if k not in b:
                    failures.append(
                        f"{name}: {k} missing from the baseline — "
                        f"regenerate {base_path}")
                    continue
                checked += 1
                old, new = b[k], f[k]
                if tol == 0.0:
                    ok = new == old
                else:
                    ok = abs(new - old) <= tol / 100.0 * abs(old) \
                        if old != 0 else new == old
                if not ok:
                    failures.append(
                        f"{name}: {k} = {fmt_val(new)} deviates from "
                        f"baseline {fmt_val(old)}"
                        + (f" beyond ±{tol}%" if tol else
                           " (exact match required)"))
    return failures, checked


def run_gate(args) -> int:
    failures, checked = gate_check(args.fresh, args.baseline, args.gate)
    lines = ["## Bench gate (deterministic metrics)", ""]
    if failures:
        lines.append(f"**FAIL** — {len(failures)} violation(s) over "
                     f"{checked} gated metric(s):")
        lines += [f"- {f}" for f in failures]
        lines.append("\nIf the change is intended, regenerate the baseline "
                     "(`PYTHONPATH=src python benchmarks/prefetch_bench.py "
                     "--smoke --out benchmarks/baselines/"
                     "BENCH_prefetch.json`) and commit it in the same PR.")
    else:
        lines.append(f"PASS — {checked} gated metrics match the checked-in "
                     "baselines exactly.")
    report = "\n".join(lines) + "\n"
    print(report)
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a") as f:
            f.write(report)
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", default=".",
                    help="directory holding the fresh BENCH_*.json files")
    ap.add_argument("--baseline", default="benchmarks/baselines")
    ap.add_argument("--gate", default=None, metavar="ALLOWLIST",
                    help="gate mode: check only the deterministic metrics "
                         "named by this allowlist (benchmarks/"
                         "gate_metrics.json) and exit non-zero on any "
                         "mismatch")
    args = ap.parse_args(argv)
    if args.gate:
        return run_gate(args)

    sections = []
    fresh_files = sorted(glob.glob(os.path.join(args.fresh, "BENCH_*.json")))
    if not fresh_files:
        sections.append("## Bench compare\n\nno fresh BENCH_*.json found — "
                        "benchmarks did not run.\n")
    for path in fresh_files:
        name = os.path.basename(path)
        base_path = os.path.join(args.baseline, name)
        if not os.path.exists(base_path):
            sections.append(f"### {name}\n\nno checked-in baseline "
                            f"(`{args.baseline}/{name}`) — add one to start "
                            "the trajectory.\n")
            continue
        with open(path) as f:
            fresh = json.load(f)
        with open(base_path) as f:
            base = json.load(f)
        rows = compare(fresh, base)
        sections.append(render(name, rows))

    report = "## Bench compare (smoke vs checked-in baselines)\n\n" + \
        "\n".join(sections) + \
        "\nShared-runner numbers are noisy; deltas are advisory " \
        "(the job stays non-blocking).\n"
    print(report)
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a") as f:
            f.write(report)
    return 0


if __name__ == "__main__":
    sys.exit(main())
