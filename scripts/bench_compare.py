#!/usr/bin/env python
"""Diff fresh BENCH_*.json smoke numbers against the checked-in baselines.

The bench-smoke CI job runs the smoke benchmarks, then this script compares
every numeric metric against ``benchmarks/baselines/BENCH_*.json`` and writes
a markdown delta table to ``$GITHUB_STEP_SUMMARY`` (and stdout). The job
stays ``continue-on-error`` — shared-runner noise must not veto a correct
change — but regressions become *visible* in the PR summary instead of
silently shipping.

Comparable metrics are the flattened numeric leaves of each artifact, minus
environment-dependent keys (timestamps, one-off setup costs, env/config
records). Latency-ish keys (``*_ms``, ``p50``/``p99``, ``ms_per_step``) get
a ⚠ marker above +20%; throughput-ish keys (``goodput``, ``*_tok_s``,
``*_speedup``, ``occupancy`` — higher is better, BENCH_queue) get one below
-20% — advisory only on shared runners.

    python scripts/bench_compare.py --fresh . --baseline benchmarks/baselines
Exit code is always 0: visibility, not a gate.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

SKIP = re.compile(r"(^|\.)(unix_time|train_s|register_s|seconds|compile|"
                  r"compiles|env|config)(\.|$)")
LATENCY = re.compile(r"(_ms|p50|p99|ms_per_step)($|\.)")
# higher-is-better metrics (BENCH_queue goodput, BENCH_compression accuracy):
# warn on *decreases* instead
THROUGHPUT = re.compile(r"(goodput|_tok_s|_speedup|occupancy|auc)($|\.|_)")
WARN_PCT = 20.0


def flatten(node, prefix="") -> dict:
    out = {}
    if isinstance(node, dict):
        for k, v in node.items():
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            out.update(flatten(v, f"{prefix}{i}."))
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        out[prefix[:-1]] = float(node)
    return out


def compare(fresh: dict, base: dict) -> list[tuple]:
    f, b = flatten(fresh), flatten(base)
    rows = []
    for key in sorted(set(f) & set(b)):
        if SKIP.search(key):
            continue
        new, old = f[key], b[key]
        if old == 0:
            delta = 0.0 if new == 0 else float("inf")
        else:
            delta = (new - old) / abs(old) * 100.0
        rows.append((key, old, new, delta))
    return rows


def fmt_val(x: float) -> str:
    return f"{x:.4g}"


def render(name: str, rows: list[tuple], top: int = 12) -> str:
    lines = [f"### {name}", "",
             "| metric | baseline | fresh | Δ% | |",
             "|---|---:|---:|---:|---|"]
    ranked = sorted(rows, key=lambda r: -abs(r[3]))[:top]
    for key, old, new, delta in ranked:
        warn = ""
        if LATENCY.search(key) and delta > WARN_PCT:
            warn = "⚠"
        elif THROUGHPUT.search(key) and delta < -WARN_PCT:
            warn = "⚠"
        d = "inf" if delta == float("inf") else f"{delta:+.1f}"
        lines.append(f"| `{key}` | {fmt_val(old)} | {fmt_val(new)} | {d} | "
                     f"{warn} |")
    n_more = len(rows) - len(ranked)
    if n_more > 0:
        lines.append(f"\n({n_more} more metrics within smaller deltas)")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", default=".",
                    help="directory holding the fresh BENCH_*.json files")
    ap.add_argument("--baseline", default="benchmarks/baselines")
    args = ap.parse_args(argv)

    sections = []
    fresh_files = sorted(glob.glob(os.path.join(args.fresh, "BENCH_*.json")))
    if not fresh_files:
        sections.append("## Bench compare\n\nno fresh BENCH_*.json found — "
                        "benchmarks did not run.\n")
    for path in fresh_files:
        name = os.path.basename(path)
        base_path = os.path.join(args.baseline, name)
        if not os.path.exists(base_path):
            sections.append(f"### {name}\n\nno checked-in baseline "
                            f"(`{args.baseline}/{name}`) — add one to start "
                            "the trajectory.\n")
            continue
        with open(path) as f:
            fresh = json.load(f)
        with open(base_path) as f:
            base = json.load(f)
        rows = compare(fresh, base)
        sections.append(render(name, rows))

    report = "## Bench compare (smoke vs checked-in baselines)\n\n" + \
        "\n".join(sections) + \
        "\nShared-runner numbers are noisy; deltas are advisory " \
        "(the job stays non-blocking).\n"
    print(report)
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a") as f:
            f.write(report)
    return 0


if __name__ == "__main__":
    sys.exit(main())
